//! The unified per-row accumulator behind every row-wise SpGEMM path —
//! SMASH's hashed scratchpad idea brought to the native serving backend.
//!
//! A [`RowAccumulator`] owns three interchangeable lanes and picks one
//! per output row:
//!
//! * **dense** — the classic Gustavson accumulator (`acc`/`present`
//!   arrays of length `cols` plus a touched-column list). O(cols) memory,
//!   O(1) per product, unbeatable on heavy rows.
//! * **hash** — an open-addressing tag/value table keyed by column index
//!   with Fibonacci (multiplicative) hashing
//!   ([`crate::kernels::hashtable::hash_tag`], `HashBits::Low`) and a
//!   linear-probe walk. The table is reused across rows and grown
//!   geometrically on demand, so a worker's footprint is O(live row nnz)
//!   — never O(cols). This is what makes hypersparse wide matrices
//!   (2^20+ columns) servable: the dense lane would pin ~9 bytes × cols
//!   × workers of cache-hostile scratch.
//! * **merge** — a k-way sorted merge over the row's B-row slices via a
//!   binary merge tree (pairwise merge rounds, Du et al. arXiv:2206.06611;
//!   merge-tree framing per SpArch, arXiv:2002.08947). A row's partial
//!   products already arrive as k sorted runs (one per selected B row);
//!   when k is small the low-compression regime makes hashing redundant
//!   work — no probing, no sort at drain, just O(flops · log k) compares.
//!
//! Selection follows Nagasaka et al. (KNL hash SpGEMM, arXiv:1804.01698)
//! extended three-way: per row, compare the FLOPs upper bound
//! `Σ_{k ∈ A[i,:]} nnz(B[k,:])` — already computed for window planning —
//! against a threshold (default `cols / 16`); heavy rows go dense. Light
//! rows then split on the merge fan-in k (B rows with a nonempty slice,
//! the same per-row stat the plan's rank pass records as
//! `SymbolicPlan::row_k`): merge when `k <= merge_max_k` and the average
//! run is at least [`MERGE_MIN_AVG_RUN`] products (or k == 1 — a single
//! presorted run needs no table at all), hash otherwise. Forced
//! [`AccumMode::Dense`] / [`AccumMode::Hash`] / [`AccumMode::Merge`]
//! exist for benchmarks, the serial oracle, and `rowwise_hash`.
//!
//! **Bitwise determinism.** All three lanes fold a column's partial
//! products in identical source order (A-row order, then B-row order)
//! starting from `add(zero, first)`, and drain sorted by column. The
//! merge lane earns this the subtle way: pairwise merge rounds are
//! *stable and non-folding* — ties take the left run first, and since
//! runs are paired in A-row order, duplicates stay adjacent in source
//! order through every round; the ⊕-fold happens once at final drain,
//! left-deep exactly like the dense lane. Serial, parallel, adaptive,
//! and every forced lane are therefore bitwise identical — the test
//! suite asserts this against the [`super::gustavson`] oracle on every
//! generator.

use super::semiring::{Arithmetic, Semiring};
use super::Traffic;
use crate::config::HashBits;
use crate::formats::{Csr, Index, Value};
use crate::kernels::hashtable::{hash_tag, TableStats};

/// Empty-slot sentinel of the hash lane. Column indices are always
/// `< cols <= u32::MAX`, so the max value is never a real tag.
const EMPTY_TAG: Index = Index::MAX;
/// Smallest hash-lane capacity (power of two).
const MIN_HASH_CAP: usize = 16;
/// Tag bits handed to [`hash_tag`] (ignored by the `Low` mode's
/// Fibonacci hash, which mixes the full 64-bit key).
const TAG_BITS: u32 = 32;
/// Default adaptive threshold divisor: rows whose FLOPs upper bound is at
/// least `cols / 16` use the dense lane.
pub const HASH_THRESHOLD_DIVISOR: usize = 16;
/// Default adaptive merge-lane fan-in cap: light rows touching at most
/// this many nonempty B rows take the k-way merge lane. Merge compares
/// cost O(flops · log k) against the hash lane's O(flops) probes, so
/// only small fan-ins win; 0 disables the merge lane entirely.
pub const MERGE_MAX_K_DEFAULT: u32 = 8;
/// Adaptive merge-lane run-length floor: for fan-in k >= 2 the merge
/// lane requires an average sorted run of at least this many products
/// (`row_flops >= k * MERGE_MIN_AVG_RUN`) — shorter runs amortize
/// nothing and hash instead. k == 1 always merges: a single presorted
/// run needs neither table nor sort.
pub const MERGE_MIN_AVG_RUN: u64 = 4;
/// Buckets of [`AccumStats::merge_depth_hist`]: bucket = min(rounds, 7)
/// where `rounds = ceil(log2 k)` pairwise merge rounds collapsed the
/// row's k runs (k <= 1 lands in bucket 0).
pub const MERGE_DEPTH_BUCKETS: usize = 8;

/// Which accumulator lane a multiply uses.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum AccumMode {
    /// Per-row three-way choice off the symbolic FLOPs upper bound and
    /// the merge fan-in (the default).
    #[default]
    Adaptive,
    /// Every row through the dense lane (the pre-adaptive behaviour and
    /// the serial-oracle semantics).
    Dense,
    /// Every row through the hash lane (the SMASH scratchpad analogue).
    Hash,
    /// Every row through the k-way sorted-merge lane (binary row
    /// merging per Du et al., arXiv:2206.06611).
    Merge,
}

impl AccumMode {
    pub fn name(&self) -> &'static str {
        match self {
            AccumMode::Adaptive => "adaptive",
            AccumMode::Dense => "dense",
            AccumMode::Hash => "hash",
            AccumMode::Merge => "merge",
        }
    }

    /// Parse a CLI spelling (`adaptive|dense|hash|merge`).
    pub fn parse(s: &str) -> Option<AccumMode> {
        match s {
            "adaptive" => Some(AccumMode::Adaptive),
            "dense" => Some(AccumMode::Dense),
            "hash" => Some(AccumMode::Hash),
            "merge" => Some(AccumMode::Merge),
            _ => None,
        }
    }
}

/// The lane [`AccumPolicy::lane_for`] resolved for one row.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Lane {
    Dense,
    Hash,
    Merge,
}

/// Largest threshold the [`AccumPolicy::auto_for`] heuristic will pick:
/// `cols / 4` (a row touching a quarter of the output width is dense by
/// any reading).
pub const AUTO_DIVISOR_MIN: usize = 4;
/// Smallest threshold the heuristic will pick: `cols / 64` (below that,
/// routing near-empty rows to the dense lane costs O(cols) scratch for
/// nothing — the §7.2 memory story).
pub const AUTO_DIVISOR_MAX: usize = 64;

/// Per-row lane-selection policy: a mode plus the adaptive threshold
/// and merge fan-in cap.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct AccumPolicy {
    pub mode: AccumMode,
    /// Rows with FLOPs upper bound `>=` this go dense under
    /// [`AccumMode::Adaptive`]; ignored by the forced modes.
    pub hash_threshold: u64,
    /// Under [`AccumMode::Adaptive`], light rows whose merge fan-in is
    /// at most this (and whose runs average [`MERGE_MIN_AVG_RUN`]+
    /// products, or k == 1) take the merge lane; 0 disables the merge
    /// lane (the pre-merge two-way policy). Ignored by the forced modes.
    pub merge_max_k: u32,
}

impl AccumPolicy {
    /// Policy for a `cols`-wide output with the default threshold
    /// (`cols / 16`, min 1) and merge fan-in cap
    /// ([`MERGE_MAX_K_DEFAULT`]).
    pub fn new(mode: AccumMode, cols: usize) -> Self {
        Self {
            mode,
            hash_threshold: (cols / HASH_THRESHOLD_DIVISOR).max(1) as u64,
            merge_max_k: MERGE_MAX_K_DEFAULT,
        }
    }

    /// Override the adaptive threshold (tuning knob).
    pub fn with_threshold(mut self, threshold: u64) -> Self {
        self.hash_threshold = threshold.max(1);
        self
    }

    /// Override the adaptive merge fan-in cap (tuning knob; 0 disables
    /// the merge lane).
    pub fn with_merge_max_k(mut self, k: u32) -> Self {
        self.merge_max_k = k;
        self
    }

    /// Per-matrix heuristic threshold, picked from the symbolic
    /// FLOPs-per-row distribution of the product instead of the global
    /// `cols / 16` constant (`--accum auto`, [`AccumSpec::Auto`]).
    ///
    /// Rationale: the threshold should split the row *population*, not
    /// the column count — hub rows (the power-law tail SMASH §7.2 is
    /// about) belong in the dense lane, the typical row in the hash lane.
    /// We target twice the median positive row-FLOPs ("a few times the
    /// typical row"), snap to the power-of-two-fraction grid the sweep
    /// driver explores (`cols / 2^k`), and clamp to
    /// `[cols / AUTO_DIVISOR_MAX, cols / AUTO_DIVISOR_MIN]` so the pick
    /// never strays more than 4× from the Nagasaka-shaped default.
    ///
    /// Deterministic: depends only on `cols` and the multiset of
    /// `row_flops` values. Empty inputs fall back to the default policy.
    pub fn auto_for(cols: usize, row_flops: &[u64]) -> AccumPolicy {
        let mut policy = AccumPolicy::new(AccumMode::Adaptive, cols);
        let mut nz: Vec<u64> = row_flops.iter().copied().filter(|&f| f > 0).collect();
        if nz.is_empty() {
            return policy;
        }
        let mid = nz.len() / 2;
        let (_, &mut median, _) = nz.select_nth_unstable(mid);
        let target = (2 * median).max(1) as u128;
        let floor = (cols / AUTO_DIVISOR_MAX).max(1) as u64;
        let mut thr = (cols / AUTO_DIVISOR_MIN).max(1) as u64;
        // Halve down the power-of-two grid while the threshold is more
        // than √2 above the target (thr > target·√2 ⇔ thr² > 2·target²),
        // i.e. until we reach the grid point geometrically nearest the
        // target — or hit the clamp floor.
        while thr > floor && (thr as u128) * (thr as u128) > 2 * target * target {
            thr = (thr / 2).max(floor);
        }
        policy.hash_threshold = thr.max(1);
        policy
    }

    /// Human-readable form, e.g. `adaptive(threshold=1024, merge-k=8)`
    /// or `dense`.
    pub fn describe(&self) -> String {
        match self.mode {
            AccumMode::Adaptive => format!(
                "adaptive(threshold={}, merge-k={})",
                self.hash_threshold, self.merge_max_k
            ),
            m => m.name().to_string(),
        }
    }

    /// The three-way per-row pick. `fan_in` lazily counts the row's
    /// merge fan-in (B rows with a nonempty slice) — only evaluated for
    /// adaptive light rows with the merge lane enabled, so forced modes
    /// and dense-routed rows pay nothing for it.
    #[inline]
    fn lane_for(&self, row_flops: u64, fan_in: impl FnOnce() -> u32) -> Lane {
        match self.mode {
            AccumMode::Dense => Lane::Dense,
            AccumMode::Hash => Lane::Hash,
            AccumMode::Merge => Lane::Merge,
            AccumMode::Adaptive => {
                if row_flops >= self.hash_threshold {
                    Lane::Dense
                } else if self.merge_max_k == 0 {
                    Lane::Hash
                } else {
                    let k = fan_in();
                    if k > 0
                        && k <= self.merge_max_k
                        && (k == 1 || row_flops >= k as u64 * MERGE_MIN_AVG_RUN)
                    {
                        Lane::Merge
                    } else {
                        Lane::Hash
                    }
                }
            }
        }
    }
}

/// Merge fan-in of a row: how many of its A entries select a nonempty B
/// row — the number of sorted leaf runs a k-way merge would fuse. The
/// rank pass records the same quantity per row as `SymbolicPlan::row_k`.
#[inline]
fn merge_fan_in(acols: &[Index], b: &Csr) -> u32 {
    acols.iter().filter(|&&k| !b.row(k as usize).0.is_empty()).count() as u32
}

/// How a job *asks for* an accumulator policy — the serializable,
/// CLI-level spelling carried on
/// [`Dataflow::ParGustavson`](super::Dataflow::ParGustavson) and resolved
/// to a concrete [`AccumPolicy`] once the operands (and, for
/// [`AccumSpec::Auto`], the symbolic FLOPs distribution) are known.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AccumSpec {
    /// A fixed mode with the default adaptive threshold (`cols / 16`)
    /// and merge fan-in cap ([`MERGE_MAX_K_DEFAULT`]).
    Fixed(AccumMode),
    /// Adaptive with an explicit threshold override — the per-job tuning
    /// knob (`serve --accum-threshold N`, the `tune` sweep driver).
    AdaptiveAt(u64),
    /// Adaptive at the default threshold with an explicit merge fan-in
    /// cap — the merge-lane tuning knob (`serve --merge-max-k N`, the
    /// `tune` arbitration leg; 0 disables the merge lane).
    MergeAt(u32),
    /// Adaptive with the per-matrix heuristic threshold
    /// ([`AccumPolicy::auto_for`]) picked at serve time from the job's
    /// own symbolic plan (`--accum auto`).
    Auto,
}

impl Default for AccumSpec {
    fn default() -> Self {
        AccumSpec::Fixed(AccumMode::Adaptive)
    }
}

impl From<AccumMode> for AccumSpec {
    fn from(mode: AccumMode) -> Self {
        AccumSpec::Fixed(mode)
    }
}

impl AccumSpec {
    /// Parse a CLI spelling (`adaptive|dense|hash|merge|auto`).
    pub fn parse(s: &str) -> Option<AccumSpec> {
        match s {
            "auto" => Some(AccumSpec::Auto),
            other => AccumMode::parse(other).map(AccumSpec::Fixed),
        }
    }

    /// Display form: `adaptive`, `dense`, `hash`, `merge`, `auto`,
    /// `adaptive@N`, `merge-k@N`.
    pub fn describe(&self) -> String {
        match self {
            AccumSpec::Fixed(m) => m.name().to_string(),
            AccumSpec::AdaptiveAt(t) => format!("adaptive@{t}"),
            AccumSpec::MergeAt(k) => format!("merge-k@{k}"),
            AccumSpec::Auto => "auto".to_string(),
        }
    }

    /// Resolve to a concrete policy for a `cols`-wide product whose
    /// symbolic FLOPs-per-row are `row_flops` (only [`AccumSpec::Auto`]
    /// reads them; pass `&[]` when no plan exists yet and a default-
    /// threshold policy is acceptable).
    pub fn resolve(&self, cols: usize, row_flops: &[u64]) -> AccumPolicy {
        match self {
            AccumSpec::Fixed(mode) => AccumPolicy::new(*mode, cols),
            AccumSpec::AdaptiveAt(t) => {
                AccumPolicy::new(AccumMode::Adaptive, cols).with_threshold(*t)
            }
            AccumSpec::MergeAt(k) => {
                AccumPolicy::new(AccumMode::Adaptive, cols).with_merge_max_k(*k)
            }
            AccumSpec::Auto => AccumPolicy::auto_for(cols, row_flops),
        }
    }
}

/// Per-multiply accumulator statistics, carried on
/// [`Traffic::accum`](super::Traffic). Numeric-pass semantics:
/// `dense_rows + hash_rows + merge_rows` equals the number of output
/// rows the accumulator processed (nonempty band segments, under the
/// blocked backend).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct AccumStats {
    /// Rows routed to the dense lane.
    pub dense_rows: u64,
    /// Rows routed to the hash lane.
    pub hash_rows: u64,
    /// Rows routed to the k-way sorted-merge lane.
    pub merge_rows: u64,
    /// Merge-lane depth histogram: bucket `min(rounds, 7)` counts rows
    /// whose k runs collapsed in `rounds = ceil(log2 k)` pairwise merge
    /// rounds (k <= 1 → bucket 0). `merge_depth_hist.iter().sum() ==
    /// merge_rows`.
    pub merge_depth_hist: [u64; MERGE_DEPTH_BUCKETS],
    /// Geometric regrowths of the hash table (excludes the first
    /// allocation).
    pub growths: u64,
    /// Peak per-worker accumulator heap bytes observed (max across
    /// workers after a parallel merge) — the O(live row nnz) vs
    /// O(cols) memory story, measured.
    pub peak_bytes: u64,
    /// Hash-lane probe statistics (upserts, probes, collisions).
    pub table: TableStats,
}

impl AccumStats {
    /// Fold another worker's stats in: counters add, peaks take the max.
    pub fn merge(&mut self, o: &AccumStats) {
        self.dense_rows += o.dense_rows;
        self.hash_rows += o.hash_rows;
        self.merge_rows += o.merge_rows;
        for (bucket, &n) in self.merge_depth_hist.iter_mut().zip(&o.merge_depth_hist) {
            *bucket += n;
        }
        self.growths += o.growths;
        self.peak_bytes = self.peak_bytes.max(o.peak_bytes);
        self.table.merge(o.table);
    }
}

/// A reusable per-row accumulator with a dense and a hash lane. One per
/// worker; every lane's scratch is lazily allocated and reused across
/// rows, so a worker that only ever hashes never pays O(cols) memory.
///
/// Generic over the [`Semiring`] whose ⊕/⊗ the numeric pass applies —
/// [`Arithmetic`] by default, so the SMASH serving paths are unchanged;
/// the graph workloads instantiate Boolean / min-plus / max-times lanes
/// over the *same* machinery ([`RowAccumulator::with_semiring`]). The
/// symbolic pass ([`RowAccumulator::symbolic_row`]) never reads values,
/// so it is semiring-invariant by construction.
pub struct RowAccumulator<S: Semiring = Arithmetic> {
    cols: usize,
    policy: AccumPolicy,
    semiring: S,
    /// Dense numeric lane (allocated on first dense numeric row).
    acc: Vec<Value>,
    present: Vec<bool>,
    /// Dense symbolic lane: visited-stamp array tagged by global row
    /// index (allocated on first dense symbolic row).
    stamp: Vec<u32>,
    /// Touched columns of the live dense row, in first-touch order.
    touched: Vec<Index>,
    /// Hash lane: open-addressing tag/value table (capacity a power of
    /// two, grown geometrically, reused across rows).
    tags: Vec<Index>,
    vals: Vec<Value>,
    /// Occupied slots of the live hash row (cleared per row; rebuilt on
    /// growth).
    used_slots: Vec<u32>,
    /// Sorted-drain scratch of the hash lane.
    drain_buf: Vec<(Index, Value)>,
    /// Per-A-entry `[start, end)` segment bounds of the live band
    /// ([`RowAccumulator::numeric_row_band`] scratch, reused across
    /// calls).
    seg_buf: Vec<(u32, u32)>,
    /// Merge lane: ping-pong product buffers (leaf runs, then each
    /// pairwise round's output) plus `[start, end)` run bounds into the
    /// live buffer. O(live row products), reused across rows.
    merge_buf: Vec<(Index, Value)>,
    merge_tmp: Vec<(Index, Value)>,
    run_buf: Vec<(u32, u32)>,
    run_tmp: Vec<(u32, u32)>,
    /// Cumulative statistics; snapshot via [`RowAccumulator::finish`].
    pub stats: AccumStats,
}

impl RowAccumulator<Arithmetic> {
    /// Arithmetic (+,×) accumulator for a `cols`-wide output under
    /// `policy` — the SMASH serving default. Allocates nothing until the
    /// first row demands a lane.
    pub fn new(cols: usize, policy: AccumPolicy) -> Self {
        Self::with_semiring(cols, policy, Arithmetic)
    }

    /// Convenience: arithmetic accumulator with the default threshold for
    /// `mode`.
    pub fn with_mode(cols: usize, mode: AccumMode) -> Self {
        Self::new(cols, AccumPolicy::new(mode, cols))
    }
}

impl<S: Semiring> RowAccumulator<S> {
    /// Accumulator whose numeric pass folds partial products with the
    /// given semiring's ⊕/⊗ (the graph workloads' entry point). The
    /// dense lane's scratch is initialized to — and cleared back to —
    /// `semiring.zero()`, so min-plus rows start from +∞ exactly like
    /// arithmetic rows start from 0.0.
    pub fn with_semiring(cols: usize, policy: AccumPolicy, semiring: S) -> Self {
        Self {
            cols,
            policy,
            semiring,
            acc: Vec::new(),
            present: Vec::new(),
            stamp: Vec::new(),
            touched: Vec::new(),
            tags: Vec::new(),
            vals: Vec::new(),
            used_slots: Vec::new(),
            drain_buf: Vec::new(),
            seg_buf: Vec::new(),
            merge_buf: Vec::new(),
            merge_tmp: Vec::new(),
            run_buf: Vec::new(),
            run_tmp: Vec::new(),
            stats: AccumStats::default(),
        }
    }

    /// Heap bytes currently held by the accumulator's lanes and scratch.
    /// O(cols) only if a dense row ever materialized a dense lane.
    pub fn resident_bytes(&self) -> usize {
        self.acc.len() * std::mem::size_of::<Value>()
            + self.present.len()
            + self.stamp.len() * std::mem::size_of::<u32>()
            + self.touched.capacity() * std::mem::size_of::<Index>()
            + self.tags.len() * std::mem::size_of::<Index>()
            + self.vals.len() * std::mem::size_of::<Value>()
            + self.used_slots.capacity() * std::mem::size_of::<u32>()
            + self.drain_buf.capacity() * std::mem::size_of::<(Index, Value)>()
            + self.seg_buf.capacity() * std::mem::size_of::<(u32, u32)>()
            + self.merge_buf.capacity() * std::mem::size_of::<(Index, Value)>()
            + self.merge_tmp.capacity() * std::mem::size_of::<(Index, Value)>()
            + self.run_buf.capacity() * std::mem::size_of::<(u32, u32)>()
            + self.run_tmp.capacity() * std::mem::size_of::<(u32, u32)>()
    }

    /// Snapshot the stats with the current footprint as `peak_bytes` —
    /// what a worker stores into its `Traffic` share when its chunk ends.
    pub fn finish(&self) -> AccumStats {
        let mut s = self.stats;
        s.peak_bytes = s.peak_bytes.max(self.resident_bytes() as u64);
        s
    }

    /// Distinct-column count of output row `i` (one symbolic-phase step).
    /// `row_flops` is the row's FLOPs upper bound (lane selection only —
    /// pass 0 under a forced policy). Row indices must be globally unique
    /// across all calls on one accumulator (they tag the stamp array).
    pub fn symbolic_row(&mut self, a: &Csr, b: &Csr, i: usize, row_flops: u64) -> usize {
        let (acols, _) = a.row(i);
        let policy = self.policy;
        match policy.lane_for(row_flops, || merge_fan_in(acols, b)) {
            Lane::Hash => {
                self.stats.hash_rows += 1;
                for &k in acols {
                    let (bcols, _) = b.row(k as usize);
                    for &j in bcols {
                        self.hash_upsert(j, 0.0);
                    }
                }
                let count = self.used_slots.len();
                self.clear_hash_row();
                count
            }
            Lane::Merge => {
                // The numeric merge machinery over zero payloads: run
                // collapse counts distinct columns exactly like the
                // stamp/table lanes do.
                let zero = self.semiring.zero();
                let mut buf = std::mem::take(&mut self.merge_buf);
                let mut runs = std::mem::take(&mut self.run_buf);
                buf.clear();
                runs.clear();
                for &k in acols {
                    let (bcols, _) = b.row(k as usize);
                    if bcols.is_empty() {
                        continue;
                    }
                    let start = buf.len() as u32;
                    for &j in bcols {
                        buf.push((j, zero));
                    }
                    runs.push((start, buf.len() as u32));
                }
                self.merge_buf = buf;
                self.run_buf = runs;
                self.merge_collapse(|_, _| {})
            }
            Lane::Dense => {
                self.stats.dense_rows += 1;
                if self.stamp.is_empty() && self.cols > 0 {
                    self.stamp = vec![u32::MAX; self.cols];
                }
                let tag = i as u32;
                let mut count = 0usize;
                for &k in acols {
                    let (bcols, _) = b.row(k as usize);
                    for &j in bcols {
                        if self.stamp[j as usize] != tag {
                            self.stamp[j as usize] = tag;
                            count += 1;
                        }
                    }
                }
                count
            }
        }
    }

    /// Accumulate output row `i` and drain it sorted-by-column into the
    /// row's output slices (`cols_out`/`data_out` must be exactly the
    /// row's nnz long). The one Gustavson inner loop shared by the serial
    /// oracle and both parallel backends.
    #[allow(clippy::too_many_arguments)]
    pub fn numeric_row(
        &mut self,
        a: &Csr,
        b: &Csr,
        i: usize,
        row_flops: u64,
        cols_out: &mut [Index],
        data_out: &mut [Value],
        t: &mut Traffic,
    ) {
        let mut slot = 0usize;
        let n = self.numeric_row_emit(a, b, i, row_flops, t, |j, v| {
            cols_out[slot] = j;
            data_out[slot] = v;
            slot += 1;
        });
        debug_assert_eq!(n, cols_out.len(), "row {i}: symbolic/numeric nnz mismatch");
    }

    /// Accumulate output row `i`, then emit its (column, value) pairs in
    /// strictly increasing column order. Returns the row's nnz. Partial
    /// products are added in A-row-then-B-row order in every lane, so the
    /// emitted values are bitwise lane-independent.
    pub fn numeric_row_emit(
        &mut self,
        a: &Csr,
        b: &Csr,
        i: usize,
        row_flops: u64,
        t: &mut Traffic,
        mut emit: impl FnMut(Index, Value),
    ) -> usize {
        let (acols, avals) = a.row(i);
        let policy = self.policy;
        match policy.lane_for(row_flops, || merge_fan_in(acols, b)) {
            Lane::Hash => {
                self.stats.hash_rows += 1;
                for (&k, &av) in acols.iter().zip(avals) {
                    t.a_reads += 1;
                    let (bcols, bvals) = b.row(k as usize);
                    t.b_reads += bcols.len() as u64;
                    for (&j, &bv) in bcols.iter().zip(bvals) {
                        let prod = self.semiring.mul(av, bv);
                        self.hash_upsert(j, prod);
                        t.flops += 1;
                    }
                }
                let n = self.used_slots.len();
                self.drain_buf.clear();
                for &s in &self.used_slots {
                    self.drain_buf.push((self.tags[s as usize], self.vals[s as usize]));
                }
                self.drain_buf.sort_unstable_by_key(|&(j, _)| j);
                for idx in 0..self.drain_buf.len() {
                    let (j, v) = self.drain_buf[idx];
                    emit(j, v);
                    t.c_writes += 1;
                }
                self.clear_hash_row();
                t.intermediate_peak = t.intermediate_peak.max(n as u64);
                n
            }
            Lane::Merge => {
                // Leaf runs: each A entry contributes its B-row slice as
                // one presorted run of partial products, in A-row order.
                let mut buf = std::mem::take(&mut self.merge_buf);
                let mut runs = std::mem::take(&mut self.run_buf);
                buf.clear();
                runs.clear();
                for (&k, &av) in acols.iter().zip(avals) {
                    t.a_reads += 1;
                    let (bcols, bvals) = b.row(k as usize);
                    t.b_reads += bcols.len() as u64;
                    if bcols.is_empty() {
                        continue;
                    }
                    let start = buf.len() as u32;
                    for (&j, &bv) in bcols.iter().zip(bvals) {
                        buf.push((j, self.semiring.mul(av, bv)));
                        t.flops += 1;
                    }
                    runs.push((start, buf.len() as u32));
                }
                // The merge intermediate holds every product (pre-fold),
                // unlike the distinct-column tables of the other lanes.
                t.intermediate_peak = t.intermediate_peak.max(buf.len() as u64);
                self.merge_buf = buf;
                self.run_buf = runs;
                self.merge_collapse(|j, v| {
                    emit(j, v);
                    t.c_writes += 1;
                })
            }
            Lane::Dense => {
                self.stats.dense_rows += 1;
                let zero = self.semiring.zero();
                if self.acc.is_empty() && self.cols > 0 {
                    self.acc = vec![zero; self.cols];
                    self.present = vec![false; self.cols];
                }
                for (&k, &av) in acols.iter().zip(avals) {
                    t.a_reads += 1;
                    let (bcols, bvals) = b.row(k as usize);
                    t.b_reads += bcols.len() as u64;
                    for (&j, &bv) in bcols.iter().zip(bvals) {
                        let ju = j as usize;
                        if !self.present[ju] {
                            self.present[ju] = true;
                            self.touched.push(j);
                        }
                        // First touch folds onto the zero left in `acc` —
                        // `add(zero, prod)` — matching the hash lane's
                        // insert.
                        self.acc[ju] =
                            self.semiring.add(self.acc[ju], self.semiring.mul(av, bv));
                        t.flops += 1;
                    }
                }
                self.touched.sort_unstable();
                let n = self.touched.len();
                for idx in 0..n {
                    let j = self.touched[idx];
                    let ju = j as usize;
                    emit(j, self.acc[ju]);
                    self.acc[ju] = zero;
                    self.present[ju] = false;
                    t.c_writes += 1;
                }
                self.touched.clear();
                t.intermediate_peak = t.intermediate_peak.max(n as u64);
                n
            }
        }
    }

    /// Accumulate the segment of output row `i` whose columns fall in the
    /// band `[lo, hi)`, then emit its `(global column, value)` pairs in
    /// strictly increasing column order — the propagation-blocking
    /// numeric kernel (`par_gustavson_blocked`). Returns the segment's
    /// nnz.
    ///
    /// The accumulator must be sized to the band (`cols >= hi - lo`):
    /// dense-lane indices are rebased to band-local offsets, so the dense
    /// scratch is O(band width), never O(b.cols) — that is the whole
    /// point of banding. Lane selection uses the *band-local* FLOPs
    /// bound, counted here by binary-searching each B row's sorted column
    /// list for the band segment (index probes — not charged as
    /// `b_reads`; only segment values actually multiplied are).
    ///
    /// Bitwise contract: every output column lives in exactly one band,
    /// and within the band partial products fold in the same
    /// A-row-then-B-row order as [`RowAccumulator::numeric_row_emit`]
    /// folds them — so per-column values are bitwise identical to the
    /// unblocked lanes, and concatenating per-band drains in ascending
    /// band order reproduces the full row in ascending column order.
    pub fn numeric_row_band(
        &mut self,
        a: &Csr,
        b: &Csr,
        i: usize,
        band: (usize, usize),
        t: &mut Traffic,
        mut emit: impl FnMut(Index, Value),
    ) -> usize {
        let (lo, hi) = band;
        debug_assert!(hi - lo <= self.cols, "band wider than the accumulator");
        let (acols, avals) = a.row(i);
        // Segment pass: locate each B row's [lo, hi) column range and sum
        // the band-local FLOPs bound that picks the lane.
        let mut seg = std::mem::take(&mut self.seg_buf);
        seg.clear();
        let mut band_flops = 0u64;
        for &k in acols {
            let (bcols, _) = b.row(k as usize);
            let s = bcols.partition_point(|&j| (j as usize) < lo);
            let e = bcols.partition_point(|&j| (j as usize) < hi);
            seg.push((s as u32, e as u32));
            band_flops += (e - s) as u64;
        }
        if band_flops == 0 {
            // Nothing of row `i` lands in this band: no lane fires and no
            // element is read, so the segment does not count toward the
            // routed-row stats.
            self.seg_buf = seg;
            return 0;
        }
        t.a_reads += acols.len() as u64;
        let policy = self.policy;
        let lane = policy.lane_for(band_flops, || {
            seg.iter().filter(|&&(s, e)| e > s).count() as u32
        });
        let n = match lane {
            Lane::Hash => {
                self.stats.hash_rows += 1;
                for ((&k, &av), &(s, e)) in acols.iter().zip(avals).zip(&seg) {
                    let (bcols, bvals) = b.row(k as usize);
                    t.b_reads += (e - s) as u64;
                    for idx in s as usize..e as usize {
                        let prod = self.semiring.mul(av, bvals[idx]);
                        self.hash_upsert(bcols[idx], prod);
                        t.flops += 1;
                    }
                }
                let n = self.used_slots.len();
                self.drain_buf.clear();
                for &s in &self.used_slots {
                    self.drain_buf.push((self.tags[s as usize], self.vals[s as usize]));
                }
                self.drain_buf.sort_unstable_by_key(|&(j, _)| j);
                for idx in 0..self.drain_buf.len() {
                    let (j, v) = self.drain_buf[idx];
                    emit(j, v);
                    t.c_writes += 1;
                }
                self.clear_hash_row();
                n
            }
            Lane::Merge => {
                // Leaf runs from the clamped segments. The merge lane
                // never indexes by column, so no band-local rebase is
                // needed: segment slices are already sorted and confined
                // to [lo, hi), and global columns are emitted as-is.
                let mut buf = std::mem::take(&mut self.merge_buf);
                let mut runs = std::mem::take(&mut self.run_buf);
                buf.clear();
                runs.clear();
                for ((&k, &av), &(s, e)) in acols.iter().zip(avals).zip(&seg) {
                    let (bcols, bvals) = b.row(k as usize);
                    t.b_reads += (e - s) as u64;
                    if e == s {
                        continue;
                    }
                    let start = buf.len() as u32;
                    for idx in s as usize..e as usize {
                        buf.push((bcols[idx], self.semiring.mul(av, bvals[idx])));
                        t.flops += 1;
                    }
                    runs.push((start, buf.len() as u32));
                }
                // The merge intermediate holds every segment product.
                t.intermediate_peak = t.intermediate_peak.max(buf.len() as u64);
                self.merge_buf = buf;
                self.run_buf = runs;
                self.merge_collapse(|j, v| {
                    emit(j, v);
                    t.c_writes += 1;
                })
            }
            Lane::Dense => {
                self.stats.dense_rows += 1;
                let zero = self.semiring.zero();
                if self.acc.is_empty() && self.cols > 0 {
                    self.acc = vec![zero; self.cols];
                    self.present = vec![false; self.cols];
                }
                for ((&k, &av), &(s, e)) in acols.iter().zip(avals).zip(&seg) {
                    let (bcols, bvals) = b.row(k as usize);
                    t.b_reads += (e - s) as u64;
                    for idx in s as usize..e as usize {
                        // Band-local rebase: the dense lane never indexes
                        // past the band width.
                        let jl = bcols[idx] as usize - lo;
                        if !self.present[jl] {
                            self.present[jl] = true;
                            self.touched.push(jl as Index);
                        }
                        self.acc[jl] =
                            self.semiring.add(self.acc[jl], self.semiring.mul(av, bvals[idx]));
                        t.flops += 1;
                    }
                }
                self.touched.sort_unstable();
                let n = self.touched.len();
                for idx in 0..n {
                    let jl = self.touched[idx] as usize;
                    emit((jl + lo) as Index, self.acc[jl]);
                    self.acc[jl] = zero;
                    self.present[jl] = false;
                    t.c_writes += 1;
                }
                self.touched.clear();
                n
            }
        };
        t.intermediate_peak = t.intermediate_peak.max(n as u64);
        self.seg_buf = seg;
        n
    }

    /// Merge `val` under column `j` in the hash lane: Fibonacci hash,
    /// linear-probe walk, growth only when an actual *insert* would cross
    /// 1/2 load (merges never grow — occupancy is unchanged), so the walk
    /// always terminates at an empty slot and the table stays at most
    /// half full.
    #[inline]
    fn hash_upsert(&mut self, j: Index, val: Value) {
        if self.tags.is_empty() {
            self.grow_hash();
        }
        'table: loop {
            let cap = self.tags.len();
            let mask = cap - 1;
            let mut slot = hash_tag(j as u64, cap, TAG_BITS, HashBits::Low);
            let mut probes = 1u32;
            loop {
                let tag = self.tags[slot];
                if tag == EMPTY_TAG {
                    if (self.used_slots.len() + 1) * 2 > cap {
                        // This insert would cross half load: double and
                        // re-probe in the grown table (one pass suffices —
                        // the doubled capacity is at least live + 2 slots).
                        self.grow_hash();
                        continue 'table;
                    }
                    self.tags[slot] = j;
                    // `add(zero, val)`, not `val`: the dense lane's first
                    // touch folds onto the zero left in `acc`, and the
                    // fold can change the bits — under arithmetic, IEEE
                    // 754 maps -0.0 to +0.0 in `0.0 + val`; under boolean,
                    // `add` re-normalizes to {0,1}. Storing `val` verbatim
                    // would diverge bitwise from the oracle.
                    self.vals[slot] = self.semiring.add(self.semiring.zero(), val);
                    self.used_slots.push(slot as u32);
                    self.stats.table.record(probes, true);
                    return;
                }
                if tag == j {
                    self.vals[slot] = self.semiring.add(self.vals[slot], val);
                    self.stats.table.record(probes, false);
                    return;
                }
                slot = (slot + 1) & mask;
                probes += 1;
            }
        }
    }

    /// Double the hash table (first call allocates [`MIN_HASH_CAP`]) and
    /// re-insert the live row's entries.
    #[cold]
    fn grow_hash(&mut self) {
        let zero = self.semiring.zero();
        let new_cap = (self.tags.len() * 2).max(MIN_HASH_CAP);
        let old_tags = std::mem::replace(&mut self.tags, vec![EMPTY_TAG; new_cap]);
        let old_vals = std::mem::replace(&mut self.vals, vec![zero; new_cap]);
        if !old_tags.is_empty() {
            self.stats.growths += 1;
        }
        self.used_slots.clear();
        let mask = new_cap - 1;
        for (s, &tag) in old_tags.iter().enumerate() {
            if tag == EMPTY_TAG {
                continue;
            }
            let mut slot = hash_tag(tag as u64, new_cap, TAG_BITS, HashBits::Low);
            while self.tags[slot] != EMPTY_TAG {
                slot = (slot + 1) & mask;
            }
            self.tags[slot] = tag;
            self.vals[slot] = old_vals[s];
            self.used_slots.push(slot as u32);
        }
    }

    /// Reset the live row's hash slots (O(row nnz), not O(capacity)).
    fn clear_hash_row(&mut self) {
        let zero = self.semiring.zero();
        for &s in &self.used_slots {
            self.tags[s as usize] = EMPTY_TAG;
            self.vals[s as usize] = zero;
        }
        self.used_slots.clear();
    }

    /// Collapse the leaf runs staged in `merge_buf`/`run_buf` (sorted,
    /// in A-row order) down to one run via stable pairwise merge rounds,
    /// then ⊕-fold duplicate columns in source order and emit strictly
    /// by ascending column. Returns the row's distinct-column count and
    /// records the merge-lane stats (row count + depth histogram).
    ///
    /// Bitwise contract: the rounds never fold — a balanced-tree fold
    /// would re-associate the float reduction. Ties take the left run
    /// first, and adjacent runs are always in A-row order, so a column's
    /// duplicates stay in global source order through every round; the
    /// single fold at drain is then `add(zero, p₁)`, `add(·, p₂)`, … —
    /// left-deep, exactly the dense lane's first-touch-then-fold.
    fn merge_collapse(&mut self, mut emit: impl FnMut(Index, Value)) -> usize {
        let mut src = std::mem::take(&mut self.merge_buf);
        let mut dst = std::mem::take(&mut self.merge_tmp);
        let mut runs = std::mem::take(&mut self.run_buf);
        let mut runs_next = std::mem::take(&mut self.run_tmp);
        let mut depth = 0usize;
        while runs.len() > 1 {
            depth += 1;
            dst.clear();
            runs_next.clear();
            for pair in runs.chunks(2) {
                let start = dst.len() as u32;
                match *pair {
                    [(ls, le), (rs, re)] => {
                        let (mut li, le) = (ls as usize, le as usize);
                        let (mut ri, re) = (rs as usize, re as usize);
                        while li < le && ri < re {
                            // `<`, not `<=`: equal columns take the left
                            // (earlier-source) run first — stability.
                            if src[ri].0 < src[li].0 {
                                dst.push(src[ri]);
                                ri += 1;
                            } else {
                                dst.push(src[li]);
                                li += 1;
                            }
                        }
                        dst.extend_from_slice(&src[li..le]);
                        dst.extend_from_slice(&src[ri..re]);
                    }
                    // Odd run out: carried to the next round verbatim.
                    [(s, e)] => dst.extend_from_slice(&src[s as usize..e as usize]),
                    _ => unreachable!("chunks(2) yields 1- or 2-run windows"),
                }
                runs_next.push((start, dst.len() as u32));
            }
            std::mem::swap(&mut src, &mut dst);
            std::mem::swap(&mut runs, &mut runs_next);
        }
        self.stats.merge_rows += 1;
        self.stats.merge_depth_hist[depth.min(MERGE_DEPTH_BUCKETS - 1)] += 1;
        let mut n = 0usize;
        if let Some(&(s, e)) = runs.first() {
            let run = &src[s as usize..e as usize];
            let mut idx = 0usize;
            while idx < run.len() {
                let j = run[idx].0;
                // First touch folds onto zero — matching the other lanes.
                let mut v = self.semiring.add(self.semiring.zero(), run[idx].1);
                idx += 1;
                while idx < run.len() && run[idx].0 == j {
                    v = self.semiring.add(v, run[idx].1);
                    idx += 1;
                }
                emit(j, v);
                n += 1;
            }
        }
        self.merge_buf = src;
        self.merge_tmp = dst;
        self.run_buf = runs;
        self.run_tmp = runs_next;
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{banded, diagonal_noise, erdos_renyi, rmat, RmatParams};
    use crate::spgemm::{flops_per_row, gustavson, symbolic_row_nnz};

    /// Drive one full multiply through a fresh accumulator and return the
    /// triplets plus traffic.
    fn multiply(a: &Csr, b: &Csr, mode: AccumMode) -> (Csr, Traffic) {
        let flops = flops_per_row(a, b);
        let mut t = Traffic::default();
        let mut racc = RowAccumulator::with_mode(b.cols, mode);
        let mut triplets = Vec::new();
        for i in 0..a.rows {
            racc.numeric_row_emit(a, b, i, flops[i], &mut t, |j, v| {
                triplets.push((i, j as usize, v));
            });
        }
        t.accum = racc.finish();
        (Csr::from_triplets(a.rows, b.cols, triplets), t)
    }

    fn assert_bitwise(c: &Csr, oracle: &Csr, label: &str) {
        assert_eq!(c.row_ptr, oracle.row_ptr, "{label}: row_ptr");
        assert_eq!(c.col_idx, oracle.col_idx, "{label}: col_idx");
        assert_eq!(c.data, oracle.data, "{label}: data");
    }

    /// Every forced lane's output is bitwise equal to the serial oracle
    /// on every generator (same per-column accumulation order in all
    /// three lanes).
    #[test]
    fn forced_lanes_bitwise_equal_oracle_all_generators() {
        let inputs: Vec<(&str, Csr, Csr)> = vec![
            (
                "rmat",
                rmat(&RmatParams::new(7, 900, 3)),
                rmat(&RmatParams::new(7, 900, 4)),
            ),
            (
                "erdos_renyi",
                erdos_renyi(96, 700, 5),
                erdos_renyi(96, 700, 6),
            ),
            ("banded", banded(64, 3, 7), banded(64, 2, 8)),
            (
                "diagonal_noise",
                diagonal_noise(80, 240, 9),
                diagonal_noise(80, 240, 10),
            ),
        ];
        for (name, a, b) in &inputs {
            let (oracle, to) = gustavson(a, b);
            for mode in [
                AccumMode::Adaptive,
                AccumMode::Dense,
                AccumMode::Hash,
                AccumMode::Merge,
            ] {
                let (c, t) = multiply(a, b, mode);
                assert_bitwise(&c, &oracle, &format!("{name}/{}", mode.name()));
                assert_eq!(t.flops, to.flops, "{name}/{}", mode.name());
                assert_eq!(t.c_writes, to.c_writes, "{name}/{}", mode.name());
                assert_eq!(
                    t.accum.dense_rows + t.accum.hash_rows + t.accum.merge_rows,
                    a.rows as u64,
                    "{name}/{}: every row must pick exactly one lane",
                    mode.name()
                );
            }
        }
    }

    /// Empty rows: no products, no emits, no lane confusion.
    #[test]
    fn empty_rows_and_empty_matrix() {
        let a = Csr::from_triplets(4, 4, vec![(2, 1, 3.0)]);
        let b = Csr::from_triplets(4, 4, vec![(1, 0, 2.0)]);
        for mode in [
            AccumMode::Adaptive,
            AccumMode::Dense,
            AccumMode::Hash,
            AccumMode::Merge,
        ] {
            let (c, t) = multiply(&a, &b, mode);
            assert_eq!(c.nnz(), 1);
            assert_eq!(c.row(2), (&[0 as Index][..], &[6.0 as Value][..]));
            assert_eq!(t.flops, 1);
        }
        let z = Csr::zero(3, 3);
        for mode in [AccumMode::Dense, AccumMode::Hash, AccumMode::Merge] {
            let (c, t) = multiply(&z, &z, mode);
            assert_eq!(c.nnz(), 0);
            assert_eq!(t.flops, 0);
            assert_eq!(t.accum.dense_rows + t.accum.hash_rows + t.accum.merge_rows, 3);
        }
    }

    /// Single-element rows through every lane.
    #[test]
    fn single_element_rows() {
        let a = Csr::from_triplets(1, 1, vec![(0, 0, 3.0)]);
        for mode in [AccumMode::Dense, AccumMode::Hash, AccumMode::Merge] {
            let (c, t) = multiply(&a, &a, mode);
            assert_eq!(c.row(0).1, &[9.0]);
            assert_eq!(t.flops, 1);
        }
    }

    /// The adaptive three-way split on one crafted wide matrix: the hub
    /// row goes dense (FLOPs over threshold), single-source rows (k = 1)
    /// take the merge lane, a 2-source row with runs too short to
    /// amortize merging hashes, and a row fanning into more than
    /// `merge_max_k` B rows hashes — and the output still matches the
    /// oracle bitwise.
    #[test]
    fn adaptive_splits_heavy_and_light_rows_on_wide_matrix() {
        let cols = 4096;
        // row 0 of A is a hub hitting a dense B row; rows 1..16 are
        // single-source; row 16 is a short 2-source row; row 17 fans
        // into 9 single-element B rows.
        let mut tr = vec![(0usize, 0usize, 1.0)];
        for r in 1..16 {
            tr.push((r, r, 1.0));
        }
        tr.push((16, 100, 1.0));
        tr.push((16, 101, 1.0));
        for s in 0..9 {
            tr.push((17, 100 + s, 1.0));
        }
        let a = Csr::from_triplets(18, cols, tr);
        let mut btr: Vec<(usize, usize, f64)> = (0..cols).map(|c| (0usize, c, 0.5)).collect();
        for r in 1..16 {
            btr.push((r, r, 2.0));
        }
        for s in 0..9 {
            btr.push((100 + s, 200 + s, 3.0));
        }
        let b = Csr::from_triplets(cols, cols, btr);
        let flops = flops_per_row(&a, &b);
        assert!(flops[0] >= (cols / HASH_THRESHOLD_DIVISOR) as u64);
        // Row 16: k=2, flops=2 < 2 * MERGE_MIN_AVG_RUN. Row 17: k=9 >
        // MERGE_MAX_K_DEFAULT. Both must hash.
        assert_eq!(flops[16], 2);
        assert_eq!(flops[17], 9);
        let (oracle, _) = gustavson(&a, &b);
        let (c, t) = multiply(&a, &b, AccumMode::Adaptive);
        assert_bitwise(&c, &oracle, "adaptive wide");
        assert_eq!(t.accum.dense_rows, 1, "only the hub row crosses the threshold");
        assert_eq!(t.accum.merge_rows, 15, "single-source rows take the merge lane");
        assert_eq!(t.accum.hash_rows, 2, "short-run and wide-fan-in rows hash");
        // k=1 rows need zero merge rounds: all 15 land in depth bucket 0.
        assert_eq!(t.accum.merge_depth_hist[0], 15);
    }

    /// The hash table grows geometrically across rows (capacity persists
    /// between rows, growth re-inserts live entries correctly).
    #[test]
    fn hash_table_grows_across_rows() {
        let n = 512;
        // Row r of A selects B rows 0..=r, B row k holds one element, so
        // row sizes ramp from 1 to n live entries.
        let a = Csr::from_triplets(
            n,
            n,
            (0..n)
                .flat_map(|r| (0..=r).map(move |k| (r, k, 1.0)))
                .collect::<Vec<_>>(),
        );
        let b = Csr::from_triplets(
            n,
            n,
            (0..n).map(|k| (k, k, 1.0 + k as f64)).collect::<Vec<_>>(),
        );
        let (oracle, _) = gustavson(&a, &b);
        let (c, t) = multiply(&a, &b, AccumMode::Hash);
        assert_bitwise(&c, &oracle, "growth ramp");
        assert!(
            t.accum.growths >= 4,
            "ramp to {n} live entries must regrow repeatedly: {} growths",
            t.accum.growths
        );
        assert_eq!(t.accum.hash_rows, n as u64);
    }

    /// §7.2 regression: Fibonacci hashing keeps the probe walk short on
    /// power-law (R-MAT) inputs — the pure low-bit mask hash this lane
    /// replaced degenerated to hundreds of probes per upsert there.
    #[test]
    fn power_law_probe_counts_stay_bounded() {
        let a = rmat(&RmatParams::new(9, 6_000, 11));
        let b = rmat(&RmatParams::new(9, 6_000, 12));
        let (_, t) = multiply(&a, &b, AccumMode::Hash);
        let mean = t.accum.table.mean_probes();
        assert!(
            mean < 2.5,
            "power-law mean probes/upsert {mean:.2} — hotspot pathology is back"
        );
        assert!(t.accum.table.upserts > 0);
    }

    /// Forced-hash never materializes the dense lane: footprint stays
    /// O(live row nnz) on a wide hypersparse input. The bound below is
    /// guaranteed: live entries per row never exceed nnz(B), so the
    /// table caps far under the 9-bytes-per-column dense floor.
    #[test]
    fn hash_lane_memory_is_o_live_row_nnz() {
        let n = 1 << 17;
        let a = rmat(&RmatParams::new(17, 4_000, 21));
        let b = rmat(&RmatParams::new(17, 4_000, 22));
        assert_eq!(b.cols, n);
        let (_, t) = multiply(&a, &b, AccumMode::Hash);
        let dense_bytes = (n * 9) as u64; // acc (8 B) + present (1 B) per col
        assert!(
            t.accum.peak_bytes * 2 < dense_bytes,
            "hash lane used {} B, dense lane would pin {} B",
            t.accum.peak_bytes,
            dense_bytes
        );
    }

    /// Symbolic counts agree between lanes and with the serial oracle.
    #[test]
    fn symbolic_counts_lane_independent() {
        let a = rmat(&RmatParams::new(7, 800, 31));
        let b = rmat(&RmatParams::new(7, 800, 32));
        let oracle = symbolic_row_nnz(&a, &b);
        let flops = flops_per_row(&a, &b);
        for mode in [
            AccumMode::Adaptive,
            AccumMode::Dense,
            AccumMode::Hash,
            AccumMode::Merge,
        ] {
            let mut racc = RowAccumulator::with_mode(b.cols, mode);
            for i in 0..a.rows {
                assert_eq!(
                    racc.symbolic_row(&a, &b, i, flops[i]),
                    oracle[i],
                    "row {i} under {}",
                    mode.name()
                );
            }
        }
    }

    /// The `auto_for` heuristic is deterministic (same inputs → same
    /// policy), always adaptive, and clamped to the documented
    /// power-of-two-fraction grid.
    #[test]
    fn auto_for_is_deterministic_and_clamped() {
        let inputs: Vec<(&str, Csr, Csr)> = vec![
            (
                "rmat",
                rmat(&RmatParams::new(8, 2_600, 101)),
                rmat(&RmatParams::new(8, 2_600, 102)),
            ),
            (
                "erdos_renyi",
                erdos_renyi(128, 1_200, 103),
                erdos_renyi(128, 1_200, 104),
            ),
            ("banded", banded(96, 4, 105), banded(96, 3, 106)),
            (
                "hypersparse",
                erdos_renyi(1 << 15, 4_000, 107),
                erdos_renyi(1 << 15, 4_000, 108),
            ),
        ];
        for (name, a, b) in &inputs {
            let flops = flops_per_row(a, b);
            let p1 = AccumPolicy::auto_for(b.cols, &flops);
            let p2 = AccumPolicy::auto_for(b.cols, &flops);
            assert_eq!(p1, p2, "{name}: auto_for must be deterministic");
            assert_eq!(p1.mode, AccumMode::Adaptive, "{name}");
            let floor = (b.cols / AUTO_DIVISOR_MAX).max(1) as u64;
            let ceil = (b.cols / AUTO_DIVISOR_MIN).max(1) as u64;
            assert!(
                p1.hash_threshold >= floor && p1.hash_threshold <= ceil,
                "{name}: auto threshold {} outside [{floor}, {ceil}]",
                p1.hash_threshold
            );
            // The resolved policy still produces the oracle product.
            let (oracle, _) = gustavson(a, b);
            let mut t = Traffic::default();
            let mut racc = RowAccumulator::new(b.cols, p1);
            let mut triplets = Vec::new();
            for i in 0..a.rows {
                racc.numeric_row_emit(a, b, i, flops[i], &mut t, |j, v| {
                    triplets.push((i, j as usize, v));
                });
            }
            let c = Csr::from_triplets(a.rows, b.cols, triplets);
            assert_bitwise(&c, &oracle, &format!("{name}/auto"));
        }
        // Degenerate shapes fall back to the default policy.
        assert_eq!(
            AccumPolicy::auto_for(64, &[]),
            AccumPolicy::new(AccumMode::Adaptive, 64)
        );
        assert_eq!(
            AccumPolicy::auto_for(64, &[0, 0, 0]),
            AccumPolicy::new(AccumMode::Adaptive, 64)
        );
        assert!(AccumPolicy::auto_for(0, &[3, 5]).hash_threshold >= 1);
    }

    /// `AccumSpec` parsing, display, and resolution round-trip.
    #[test]
    fn accum_spec_parse_and_resolve() {
        assert_eq!(
            AccumSpec::parse("adaptive"),
            Some(AccumSpec::Fixed(AccumMode::Adaptive))
        );
        assert_eq!(AccumSpec::parse("dense"), Some(AccumSpec::Fixed(AccumMode::Dense)));
        assert_eq!(AccumSpec::parse("hash"), Some(AccumSpec::Fixed(AccumMode::Hash)));
        assert_eq!(AccumSpec::parse("merge"), Some(AccumSpec::Fixed(AccumMode::Merge)));
        assert_eq!(AccumSpec::parse("auto"), Some(AccumSpec::Auto));
        assert_eq!(AccumSpec::parse("bogus"), None);
        assert_eq!(AccumSpec::default(), AccumMode::Adaptive.into());
        assert_eq!(AccumSpec::AdaptiveAt(512).describe(), "adaptive@512");
        assert_eq!(AccumSpec::MergeAt(4).describe(), "merge-k@4");

        let flops = vec![1u64, 2, 3, 400];
        let fixed = AccumSpec::Fixed(AccumMode::Dense).resolve(1024, &flops);
        assert_eq!(fixed.mode, AccumMode::Dense);
        assert_eq!(fixed.hash_threshold, (1024 / HASH_THRESHOLD_DIVISOR) as u64);
        let at = AccumSpec::AdaptiveAt(7).resolve(1024, &flops);
        assert_eq!(at.mode, AccumMode::Adaptive);
        assert_eq!(at.hash_threshold, 7);
        assert_eq!(at.merge_max_k, MERGE_MAX_K_DEFAULT);
        let mk = AccumSpec::MergeAt(3).resolve(1024, &flops);
        assert_eq!(mk.mode, AccumMode::Adaptive);
        assert_eq!(mk.hash_threshold, (1024 / HASH_THRESHOLD_DIVISOR) as u64);
        assert_eq!(mk.merge_max_k, 3);
        // merge_max_k = 0 disables the merge lane entirely.
        assert_eq!(AccumSpec::MergeAt(0).resolve(1024, &flops).merge_max_k, 0);
        assert_eq!(
            AccumSpec::Auto.resolve(1024, &flops),
            AccumPolicy::auto_for(1024, &flops)
        );
        // The explicit-threshold knob clamps to ≥ 1 like with_threshold.
        assert_eq!(AccumSpec::AdaptiveAt(0).resolve(64, &flops).hash_threshold, 1);
    }

    /// Semiring-generic lanes: forced-dense, forced-hash, and adaptive
    /// accumulators over every [`SemiringKind`] reproduce the serial
    /// semiring oracle bitwise — same `add(zero, prod)` first-touch, same
    /// A-row-then-B-row fold order, same sorted drain.
    #[test]
    fn semiring_lanes_bitwise_equal_serial_oracle() {
        use crate::spgemm::semiring::{spgemm_semiring, SemiringKind};
        let a = rmat(&RmatParams::new(7, 700, 201));
        let b = rmat(&RmatParams::new(7, 700, 202));
        let flops = flops_per_row(&a, &b);
        for kind in SemiringKind::ALL {
            let oracle = spgemm_semiring(&a, &b, kind);
            for mode in [
                AccumMode::Adaptive,
                AccumMode::Dense,
                AccumMode::Hash,
                AccumMode::Merge,
            ] {
                let mut racc =
                    RowAccumulator::with_semiring(b.cols, AccumPolicy::new(mode, b.cols), kind);
                let mut t = Traffic::default();
                let mut row_ptr = vec![0usize];
                let mut col_idx = Vec::new();
                let mut data = Vec::new();
                for i in 0..a.rows {
                    racc.numeric_row_emit(&a, &b, i, flops[i], &mut t, |j, v| {
                        col_idx.push(j);
                        data.push(v);
                    });
                    row_ptr.push(col_idx.len());
                }
                let c = Csr {
                    rows: a.rows,
                    cols: b.cols,
                    row_ptr,
                    col_idx,
                    data,
                };
                assert_bitwise(&c, &oracle, &format!("{}/{}", kind.name(), mode.name()));
                assert_eq!(
                    t.accum.dense_rows + t.accum.hash_rows + t.accum.merge_rows,
                    a.rows as u64,
                    "{}/{}: every row picks exactly one lane",
                    kind.name(),
                    mode.name()
                );
            }
        }
    }

    /// Band-sliced accumulation: concatenating `numeric_row_band` drains
    /// over any band width reproduces the full-width `numeric_row_emit`
    /// row bitwise, for all three lanes, and the dense scratch stays
    /// sized to the band.
    #[test]
    fn banded_rows_concatenate_to_full_rows_bitwise() {
        let a = rmat(&RmatParams::new(7, 900, 301));
        let b = rmat(&RmatParams::new(7, 900, 302));
        let flops = flops_per_row(&a, &b);
        for mode in [
            AccumMode::Adaptive,
            AccumMode::Dense,
            AccumMode::Hash,
            AccumMode::Merge,
        ] {
            // Full-width reference.
            let mut full = RowAccumulator::with_mode(b.cols, mode);
            let mut tf = Traffic::default();
            let mut want: Vec<(usize, Index, Value)> = Vec::new();
            for i in 0..a.rows {
                full.numeric_row_emit(&a, &b, i, flops[i], &mut tf, |j, v| {
                    want.push((i, j, v));
                });
            }
            for band_cols in [1usize, 7, 64, b.cols] {
                let mut racc = RowAccumulator::with_mode(band_cols, mode);
                let mut t = Traffic::default();
                let mut got: Vec<(usize, Index, Value)> = Vec::new();
                for i in 0..a.rows {
                    let mut lo = 0usize;
                    while lo < b.cols {
                        let hi = (lo + band_cols).min(b.cols);
                        racc.numeric_row_band(&a, &b, i, (lo, hi), &mut t, |j, v| {
                            got.push((i, j, v));
                        });
                        lo = hi;
                    }
                }
                assert_eq!(got, want, "{}/band={band_cols}", mode.name());
                assert_eq!(t.flops, tf.flops, "banding conserves FLOPs");
                assert_eq!(t.c_writes, tf.c_writes, "banding conserves writes");
                // The dense lane is sized to the band, not to b.cols.
                assert!(
                    racc.acc.len() <= band_cols,
                    "{}/band={band_cols}: dense lane {} cols",
                    mode.name(),
                    racc.acc.len()
                );
            }
        }
    }

    /// Map-oracle property test of the hash lane across random rows.
    #[test]
    fn prop_hash_lane_matches_map_oracle() {
        use crate::util::quick::forall;
        forall(32, |g| {
            let cols = 1usize << g.usize_in(4, 12);
            let mut racc = RowAccumulator::with_mode(cols, AccumMode::Hash);
            for _ in 0..g.usize_in(1, 4) {
                // one synthetic row of random (col, val) products
                let mut oracle = std::collections::HashMap::new();
                let n = g.usize_in(0, 200);
                let products: Vec<(Index, Value)> = (0..n)
                    .map(|_| (g.usize_in(0, cols - 1) as Index, g.f64_in(-4.0, 4.0)))
                    .collect();
                for &(j, v) in &products {
                    racc.hash_upsert(j, v);
                    *oracle.entry(j).or_insert(0.0) += v;
                }
                // drain via the emit path of a fake empty row is not
                // possible; drain manually in sorted order.
                let mut drained: Vec<(Index, Value)> = racc
                    .used_slots
                    .iter()
                    .map(|&s| (racc.tags[s as usize], racc.vals[s as usize]))
                    .collect();
                racc.clear_hash_row();
                drained.sort_unstable_by_key(|&(j, _)| j);
                let mut expect: Vec<(Index, f64)> = oracle.into_iter().collect();
                expect.sort_unstable_by_key(|&(j, _)| j);
                assert_eq!(drained.len(), expect.len());
                for ((j1, v1), (j2, v2)) in drained.iter().zip(&expect) {
                    assert_eq!(j1, j2);
                    assert!((v1 - v2).abs() < 1e-9);
                }
            }
        });
    }

    /// Drain one synthetic row (A = 1×k selecting k B-rows of sorted
    /// runs) through a lane and return the emitted pairs with values as
    /// raw bits — the exact-equality currency of the parity harness.
    fn lane_drain<S: Semiring + Copy>(
        a: &Csr,
        b: &Csr,
        mode: AccumMode,
        semiring: S,
    ) -> Vec<(Index, u64)> {
        let flops = flops_per_row(a, b);
        let mut racc =
            RowAccumulator::with_semiring(b.cols, AccumPolicy::new(mode, b.cols), semiring);
        let mut t = Traffic::default();
        let mut out = Vec::new();
        racc.numeric_row_emit(a, b, 0, flops[0], &mut t, |j, v| {
            out.push((j, v.to_bits()));
        });
        out
    }

    /// Map-oracle + three-lane parity property harness: a seeded
    /// randomized generator builds one row's (col, val) product stream —
    /// including adversarial shapes (all-duplicate columns, k = 1
    /// single-source rows, empty rows, growth-ramp run lengths) — and
    /// every lane under every semiring must produce the identical sorted
    /// drain, bit-for-bit, equal to a source-order left-deep ⊕-fold.
    #[test]
    fn prop_three_lanes_identical_drains_across_semirings() {
        use crate::spgemm::semiring::{Boolean, MaxTimes, MinPlus};
        use crate::util::quick::forall;

        fn check<S: Semiring + Copy>(g: &mut crate::util::quick::Gen, semiring: S) {
            let cols = 1usize << g.usize_in(2, 10);
            let k = g.usize_in(0, 12); // spans k=0 (empty), k=1, k>MERGE_MAX_K
            let all_dup = g.usize_in(0, 3) == 0;
            let dup_col = g.usize_in(0, cols - 1);
            let mut atr: Vec<(usize, usize, f64)> = Vec::new();
            let mut btr: Vec<(usize, usize, f64)> = Vec::new();
            for r in 0..k {
                atr.push((0, r, g.f64_in(-4.0, 4.0)));
                if all_dup {
                    // Adversarial: every run is the same single column, so
                    // all k products collide on one output entry.
                    btr.push((r, dup_col, g.f64_in(-4.0, 4.0)));
                } else {
                    // Growth-ramp lengths: run r holds up to 3r+1 random
                    // strictly increasing columns.
                    let mut c = g.usize_in(0, 7).min(cols - 1);
                    for _ in 0..g.usize_in(0, 3 * r + 1) {
                        if c >= cols {
                            break;
                        }
                        btr.push((r, c, g.f64_in(-4.0, 4.0)));
                        c += g.usize_in(1, 1 + cols / 8);
                    }
                }
            }
            let a = Csr::from_triplets(1, k.max(1), atr);
            let b = Csr::from_triplets(k.max(1), cols, btr);
            // Map-oracle: per column, a left-deep source-order fold
            // starting from add(zero, first) — the documented contract
            // of all three lanes.
            let mut expect: std::collections::BTreeMap<Index, Value> =
                std::collections::BTreeMap::new();
            let (acols, avals) = a.row(0);
            for (&bk, &av) in acols.iter().zip(avals) {
                let (bcols, bvals) = b.row(bk as usize);
                for (&j, &bv) in bcols.iter().zip(bvals) {
                    let prod = semiring.mul(av, bv);
                    match expect.entry(j) {
                        std::collections::btree_map::Entry::Vacant(e) => {
                            e.insert(semiring.add(semiring.zero(), prod));
                        }
                        std::collections::btree_map::Entry::Occupied(mut e) => {
                            let v = *e.get();
                            e.insert(semiring.add(v, prod));
                        }
                    }
                }
            }
            let want: Vec<(Index, u64)> =
                expect.iter().map(|(&j, &v)| (j, v.to_bits())).collect();
            for mode in [
                AccumMode::Dense,
                AccumMode::Hash,
                AccumMode::Merge,
                AccumMode::Adaptive,
            ] {
                let got = lane_drain(&a, &b, mode, semiring);
                assert_eq!(got, want, "{} lane drain diverged from map oracle", mode.name());
                // The symbolic pass agrees on the distinct-column count.
                let mut racc = RowAccumulator::with_semiring(
                    b.cols,
                    AccumPolicy::new(mode, b.cols),
                    semiring,
                );
                let flops = flops_per_row(&a, &b);
                assert_eq!(racc.symbolic_row(&a, &b, 0, flops[0]), want.len());
            }
        }

        forall(48, |g| {
            check(g, Arithmetic);
            check(g, Boolean);
            check(g, MinPlus);
            check(g, MaxTimes);
        });
    }

    /// `AccumStats` contract: the three lane counters partition the rows
    /// under every mode, forced modes stay exclusive (including
    /// [`AccumMode::Merge`]), and the merge-depth histogram is sane —
    /// it sums to `merge_rows` and forced-merge rows land in the
    /// `ceil(log2 k)` bucket.
    #[test]
    fn stats_contract_three_way_partition_and_depth_hist() {
        let a = rmat(&RmatParams::new(7, 900, 401));
        let b = rmat(&RmatParams::new(7, 900, 402));
        let rows = a.rows as u64;
        for mode in [
            AccumMode::Adaptive,
            AccumMode::Dense,
            AccumMode::Hash,
            AccumMode::Merge,
        ] {
            let (_, t) = multiply(&a, &b, mode);
            let s = t.accum;
            assert_eq!(
                s.dense_rows + s.hash_rows + s.merge_rows,
                rows,
                "{}: lane counters must partition the rows",
                mode.name()
            );
            assert_eq!(
                s.merge_depth_hist.iter().sum::<u64>(),
                s.merge_rows,
                "{}: depth histogram must sum to merge_rows",
                mode.name()
            );
            match mode {
                AccumMode::Dense => {
                    assert_eq!((s.hash_rows, s.merge_rows), (0, 0), "forced dense");
                }
                AccumMode::Hash => {
                    assert_eq!((s.dense_rows, s.merge_rows), (0, 0), "forced hash");
                }
                AccumMode::Merge => {
                    assert_eq!((s.dense_rows, s.hash_rows), (0, 0), "forced merge");
                }
                AccumMode::Adaptive => {}
            }
        }
        // Depth buckets: a forced-merge row with k sorted runs collapses
        // in ceil(log2 k) pairwise rounds.
        for (k, bucket) in [(1usize, 0usize), (2, 1), (3, 2), (5, 3), (8, 3), (9, 4)] {
            let atr: Vec<(usize, usize, f64)> = (0..k).map(|r| (0, r, 1.0)).collect();
            let btr: Vec<(usize, usize, f64)> = (0..k).map(|r| (r, 2 * r, 1.5)).collect();
            let a = Csr::from_triplets(1, k, atr);
            let b = Csr::from_triplets(k, 2 * k, btr);
            let (_, t) = multiply(&a, &b, AccumMode::Merge);
            assert_eq!(t.accum.merge_rows, 1);
            let mut want = [0u64; MERGE_DEPTH_BUCKETS];
            want[bucket] = 1;
            assert_eq!(
                t.accum.merge_depth_hist, want,
                "k={k} must collapse in {bucket} rounds"
            );
        }
        // Worker-merge folding: counters add, histograms add bucketwise.
        let mut acc = AccumStats::default();
        let mut w1 = AccumStats::default();
        w1.merge_rows = 2;
        w1.merge_depth_hist[0] = 1;
        w1.merge_depth_hist[3] = 1;
        let mut w2 = AccumStats::default();
        w2.merge_rows = 1;
        w2.merge_depth_hist[3] = 1;
        acc.merge(&w1);
        acc.merge(&w2);
        assert_eq!(acc.merge_rows, 3);
        assert_eq!(acc.merge_depth_hist[0], 1);
        assert_eq!(acc.merge_depth_hist[3], 2);
    }
}
