//! The plan pipeline: planning a parallel SpGEMM as composable passes.
//!
//! A plan answers three questions, each owned by one pass:
//!
//! 1. [`rank`] — *how heavy is each row?* Per-row symbolic statistics:
//!    the FLOPs upper bound (`Σ_{k ∈ A[i,:]} nnz(B[k,:])`), the merge
//!    fan-in (contributing B rows — what routes light rows between the
//!    hash and merge lanes), and the exact output nnz, computed with the
//!    same `flops_of_row` / [`RowAccumulator::symbolic_row`] kernels the
//!    serial oracle uses.
//! 2. [`partition`] — *how is the work sliced?* Row windows of roughly
//!    equal FMA volume for every parallel backend, and fixed-width column
//!    bands ([`BandSpec`]) for the propagation-blocking backend.
//! 3. [`schedule`] — *who runs which slice?* The LPT / round-robin
//!    load packer ([`schedule_loads`]), axis-free: it sees only a load
//!    vector, so row windows and column bands schedule identically.
//!
//! The passes compose into a [`SymbolicPlan`] — the reusable symbolic
//! product description the serving coordinator caches per operand pair.
//! [`symbolic_plan_serial`] is the reference composition: a
//! single-threaded, dependency-free chaining of the passes that the
//! parallel driver (`spgemm::par::symbolic_plan`) must reproduce
//! field-for-field (asserted by the pipeline unit suite below and by
//! `plan_matches_serial_symbolic` in `par.rs`).

pub mod partition;
pub mod rank;
pub mod schedule;

pub use partition::{
    auto_band_cols, partition_rows, BandPartition, BandSpec, BAND_AUTO_TARGET_BYTES,
};
pub use schedule::{schedule_loads, schedule_windows, Assignment, SchedPolicy};

use super::accumulator::{AccumSpec, RowAccumulator};
use crate::formats::Csr;

/// The reusable symbolic result of one A·B product: per-row FMA counts
/// (window planning), exact per-row output nnz, and the exclusive prefix
/// sum (`row_ptr`) of the output CSR.
///
/// Computing this once and amortizing it across a batch of jobs that
/// share operands is the serving analogue of the paper's two-step
/// symbolic/numeric split — the coordinator caches plans per registered
/// operand pair and hands them to `par_gustavson_with_plan*`. A plan is
/// independent of thread count, accumulator policy, semiring, *and* band
/// width: banding partitions the numeric pass only, never the symbolic
/// row structure.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SymbolicPlan {
    /// FMA count per output row (window planning input).
    pub row_flops: Vec<u64>,
    /// Merge fan-in per output row: the number of B rows contributing
    /// partial products (sorted runs a k-way merge would see) — the
    /// statistic the three-way accumulator policy routes light rows on.
    pub row_k: Vec<u32>,
    /// Exact nnz per output row.
    pub row_nnz: Vec<usize>,
    /// Exclusive prefix sum of `row_nnz` (`rows + 1` entries) — the
    /// output's CSR row-pointer array.
    pub row_ptr: Vec<usize>,
}

impl SymbolicPlan {
    /// Exact nnz of the product this plan describes.
    pub fn nnz(&self) -> usize {
        *self.row_ptr.last().unwrap_or(&0)
    }

    /// Approximate heap bytes held by the plan arrays (for cache
    /// accounting in the serving layer).
    pub fn resident_bytes(&self) -> usize {
        self.row_flops.len() * std::mem::size_of::<u64>()
            + self.row_k.len() * std::mem::size_of::<u32>()
            + self.row_nnz.len() * std::mem::size_of::<usize>()
            + self.row_ptr.len() * std::mem::size_of::<usize>()
    }
}

/// The reference pipeline composition: rank passes chained serially with
/// no chunking, pooling, or scheduling. The parallel driver must produce
/// exactly this plan (integer passes are exact, so chunking may not
/// change any field) — the contract that makes refactored plans
/// bit-identical for existing consumers.
pub fn symbolic_plan_serial(a: &Csr, b: &Csr, spec: AccumSpec) -> SymbolicPlan {
    assert_eq!(a.cols, b.rows, "dimension mismatch");
    let mut row_flops = vec![0u64; a.rows];
    rank::flops_chunk(a, b, 0, &mut row_flops);
    let mut row_k = vec![0u32; a.rows];
    rank::fanin_chunk(a, b, 0, &mut row_k);
    // Lane choice affects only scratch shape and stats, never the counted
    // nnz — plans stay policy-independent (same resolution point as the
    // parallel driver).
    let policy = spec.resolve(b.cols, &row_flops);
    let mut racc = RowAccumulator::new(b.cols, policy);
    let mut row_nnz = vec![0usize; a.rows];
    rank::symbolic_chunk(a, b, &mut racc, &row_flops, 0, &mut row_nnz);
    let row_ptr = rank::prefix_sum(&row_nnz);
    SymbolicPlan {
        row_flops,
        row_k,
        row_nnz,
        row_ptr,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{banded, erdos_renyi, hypersparse, rmat, RmatParams};
    use crate::spgemm::{flops_per_row, symbolic_row_nnz, AccumMode};

    /// The serial pipeline reproduces the pre-refactor `SymbolicPlan`
    /// fields exactly: `row_flops` == the standalone FLOP pass,
    /// `row_nnz` == the standalone symbolic pass, `row_ptr` == their
    /// serial prefix sum.
    #[test]
    fn serial_pipeline_reproduces_pre_refactor_plan_fields() {
        let inputs: Vec<(&str, Csr, Csr)> = vec![
            (
                "rmat",
                rmat(&RmatParams::new(8, 2_600, 61)),
                rmat(&RmatParams::new(8, 2_600, 62)),
            ),
            (
                "erdos_renyi",
                erdos_renyi(128, 1_200, 63),
                erdos_renyi(128, 1_200, 64),
            ),
            ("banded", banded(96, 4, 65), banded(96, 3, 66)),
            (
                "hypersparse",
                hypersparse(14, 2_000, 67),
                hypersparse(14, 2_000, 68),
            ),
        ];
        for (name, a, b) in &inputs {
            let plan = symbolic_plan_serial(a, b, AccumSpec::default());
            assert_eq!(plan.row_flops, flops_per_row(a, b), "{name}: row_flops");
            let mut row_k = vec![0u32; a.rows];
            rank::fanin_chunk(a, b, 0, &mut row_k);
            assert_eq!(plan.row_k, row_k, "{name}: row_k");
            for i in 0..a.rows {
                assert!(
                    u64::from(plan.row_k[i]) <= plan.row_flops[i],
                    "{name}: fan-in bounded by FLOPs at row {i}"
                );
            }
            assert_eq!(plan.row_nnz, symbolic_row_nnz(a, b), "{name}: row_nnz");
            let mut acc = 0usize;
            for (i, &n) in plan.row_nnz.iter().enumerate() {
                assert_eq!(plan.row_ptr[i], acc, "{name}: row_ptr[{i}]");
                acc += n;
            }
            assert_eq!(plan.nnz(), acc, "{name}: nnz");
        }
    }

    /// Plans are accumulator-policy independent: forced-dense, forced-hash
    /// and adaptive pipelines count the same structure.
    #[test]
    fn serial_pipeline_is_policy_independent() {
        let a = rmat(&RmatParams::new(7, 900, 71));
        let b = rmat(&RmatParams::new(7, 900, 72));
        let base = symbolic_plan_serial(&a, &b, AccumSpec::default());
        for spec in [
            AccumSpec::Fixed(AccumMode::Dense),
            AccumSpec::Fixed(AccumMode::Hash),
            AccumSpec::AdaptiveAt(3),
            AccumSpec::Auto,
        ] {
            assert_eq!(base, symbolic_plan_serial(&a, &b, spec), "{spec:?}");
        }
    }

    /// Degenerate shapes flow through the pipeline without special cases.
    #[test]
    fn serial_pipeline_degenerate_shapes() {
        let z = Csr::zero(5, 5);
        let plan = symbolic_plan_serial(&z, &z, AccumSpec::default());
        assert_eq!(plan.nnz(), 0);
        assert_eq!(plan.row_ptr, vec![0; 6]);
        let empty = Csr::zero(0, 0);
        let plan = symbolic_plan_serial(&empty, &empty, AccumSpec::default());
        assert_eq!(plan.row_ptr, vec![0]);
        assert_eq!(plan.nnz(), 0);
    }
}
