//! Rank pass: per-row symbolic statistics of the product.
//!
//! Two statistics rank a row: its FLOPs upper bound
//! (`Σ_{k ∈ A[i,:]} nnz(B[k,:])` — `flops_of_row`, what the partition and
//! schedule passes balance on) and its exact output nnz
//! ([`RowAccumulator::symbolic_row`] — what pre-allocates the product).
//!
//! The kernels here are *chunk-shaped*: they rank a contiguous row range
//! into a caller-provided slice. The serial reference pipeline
//! ([`super::symbolic_plan_serial`]) runs each over the full row range;
//! the parallel driver (`spgemm::par`) runs the very same kernels over
//! disjoint chunks on the worker pool — which is why parallel plans are
//! bit-identical to serial ones (integer statistics, exact chunked
//! prefix sum).

use crate::formats::Csr;
use crate::spgemm::accumulator::RowAccumulator;
use crate::spgemm::gustavson::flops_of_row;
use crate::spgemm::semiring::Semiring;

/// FLOPs-upper-bound statistic over rows `begin .. begin + out.len()`.
pub fn flops_chunk(a: &Csr, b: &Csr, begin: usize, out: &mut [u64]) {
    for (off, f) in out.iter_mut().enumerate() {
        *f = flops_of_row(a, b, begin + off);
    }
}

/// Exact-output-nnz statistic over rows `begin .. begin + out.len()`,
/// using a caller-owned accumulator (one per worker — lane scratch is
/// reused across the chunk's rows). `row_flops` must be the full-length
/// FLOP statistic; it drives per-row lane selection only and never
/// changes the counted nnz.
pub fn symbolic_chunk<S: Semiring>(
    a: &Csr,
    b: &Csr,
    racc: &mut RowAccumulator<S>,
    row_flops: &[u64],
    begin: usize,
    out: &mut [usize],
) {
    for (off, slot) in out.iter_mut().enumerate() {
        let i = begin + off;
        *slot = racc.symbolic_row(a, b, i, row_flops[i]);
    }
}

/// Exclusive prefix sum of the per-row nnz statistic — the output CSR's
/// row-pointer array (`rows + 1` entries). The serial reference; the
/// parallel driver's two-pass scan must (and does) produce identical
/// values, since integer addition is exact.
pub fn prefix_sum(row_nnz: &[usize]) -> Vec<usize> {
    let mut row_ptr = vec![0usize; row_nnz.len() + 1];
    let mut acc = 0usize;
    for (i, &n) in row_nnz.iter().enumerate() {
        acc += n;
        row_ptr[i + 1] = acc;
    }
    row_ptr
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{rmat, RmatParams};
    use crate::spgemm::{flops_per_row, symbolic_row_nnz, AccumMode, AccumPolicy};

    /// Chunked execution is invariant: any chunking of the row range
    /// produces the same statistics as one full-range call.
    #[test]
    fn chunked_ranking_equals_full_range() {
        let a = rmat(&RmatParams::new(7, 900, 81));
        let b = rmat(&RmatParams::new(7, 900, 82));
        let full_flops = flops_per_row(&a, &b);
        let full_nnz = symbolic_row_nnz(&a, &b);
        for parts in [1usize, 2, 3, 7] {
            let mut flops = vec![0u64; a.rows];
            let mut nnz = vec![0usize; a.rows];
            let chunk = a.rows.div_ceil(parts);
            let mut racc =
                RowAccumulator::new(b.cols, AccumPolicy::new(AccumMode::Adaptive, b.cols));
            let mut begin = 0usize;
            while begin < a.rows {
                let end = (begin + chunk).min(a.rows);
                flops_chunk(&a, &b, begin, &mut flops[begin..end]);
                symbolic_chunk(&a, &b, &mut racc, &full_flops, begin, &mut nnz[begin..end]);
                begin = end;
            }
            assert_eq!(flops, full_flops, "parts={parts}");
            assert_eq!(nnz, full_nnz, "parts={parts}");
        }
    }

    #[test]
    fn prefix_sum_is_exclusive_and_totals() {
        assert_eq!(prefix_sum(&[]), vec![0]);
        assert_eq!(prefix_sum(&[3, 0, 2, 5]), vec![0, 3, 3, 5, 10]);
    }
}
