//! Rank pass: per-row symbolic statistics of the product.
//!
//! Three statistics rank a row: its FLOPs upper bound
//! (`Σ_{k ∈ A[i,:]} nnz(B[k,:])` — `flops_of_row`, what the partition and
//! schedule passes balance on), its merge fan-in (`fanin_chunk` — the
//! number of B rows contributing partial products, i.e. how many sorted
//! runs a k-way merge of the row would see; what the three-way
//! accumulator policy routes on), and its exact output nnz
//! ([`RowAccumulator::symbolic_row`] — what pre-allocates the product).
//!
//! The kernels here are *chunk-shaped*: they rank a contiguous row range
//! into a caller-provided slice. The serial reference pipeline
//! ([`super::symbolic_plan_serial`]) runs each over the full row range;
//! the parallel driver (`spgemm::par`) runs the very same kernels over
//! disjoint chunks on the worker pool — which is why parallel plans are
//! bit-identical to serial ones (integer statistics, exact chunked
//! prefix sum).

use crate::formats::Csr;
use crate::spgemm::accumulator::RowAccumulator;
use crate::spgemm::gustavson::flops_of_row;
use crate::spgemm::semiring::Semiring;

/// FLOPs-upper-bound statistic over rows `begin .. begin + out.len()`.
pub fn flops_chunk(a: &Csr, b: &Csr, begin: usize, out: &mut [u64]) {
    for (off, f) in out.iter_mut().enumerate() {
        *f = flops_of_row(a, b, begin + off);
    }
}

/// Merge fan-in statistic over rows `begin .. begin + out.len()`: the
/// number of A-row entries whose B row is nonempty — the count of sorted
/// runs the merge lane would collapse, and the `k` the adaptive policy
/// compares against `merge_max_k`. Kept `u32`: fan-in is bounded by
/// `nnz(A[i,:])`, and the accumulator's k-way routing saturates far
/// below that.
pub fn fanin_chunk(a: &Csr, b: &Csr, begin: usize, out: &mut [u32]) {
    for (off, k) in out.iter_mut().enumerate() {
        let (acols, _) = a.row(begin + off);
        *k = acols
            .iter()
            .filter(|&&kk| !b.row(kk as usize).0.is_empty())
            .count() as u32;
    }
}

/// Exact-output-nnz statistic over rows `begin .. begin + out.len()`,
/// using a caller-owned accumulator (one per worker — lane scratch is
/// reused across the chunk's rows). `row_flops` must be the full-length
/// FLOP statistic; it drives per-row lane selection only and never
/// changes the counted nnz.
pub fn symbolic_chunk<S: Semiring>(
    a: &Csr,
    b: &Csr,
    racc: &mut RowAccumulator<S>,
    row_flops: &[u64],
    begin: usize,
    out: &mut [usize],
) {
    for (off, slot) in out.iter_mut().enumerate() {
        let i = begin + off;
        *slot = racc.symbolic_row(a, b, i, row_flops[i]);
    }
}

/// Exclusive prefix sum of the per-row nnz statistic — the output CSR's
/// row-pointer array (`rows + 1` entries). The serial reference; the
/// parallel driver's two-pass scan must (and does) produce identical
/// values, since integer addition is exact.
pub fn prefix_sum(row_nnz: &[usize]) -> Vec<usize> {
    let mut row_ptr = vec![0usize; row_nnz.len() + 1];
    let mut acc = 0usize;
    for (i, &n) in row_nnz.iter().enumerate() {
        acc += n;
        row_ptr[i + 1] = acc;
    }
    row_ptr
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{rmat, RmatParams};
    use crate::spgemm::{flops_per_row, symbolic_row_nnz, AccumMode, AccumPolicy};

    /// Chunked execution is invariant: any chunking of the row range
    /// produces the same statistics as one full-range call.
    #[test]
    fn chunked_ranking_equals_full_range() {
        let a = rmat(&RmatParams::new(7, 900, 81));
        let b = rmat(&RmatParams::new(7, 900, 82));
        let full_flops = flops_per_row(&a, &b);
        let full_nnz = symbolic_row_nnz(&a, &b);
        let mut full_k = vec![0u32; a.rows];
        fanin_chunk(&a, &b, 0, &mut full_k);
        for parts in [1usize, 2, 3, 7] {
            let mut flops = vec![0u64; a.rows];
            let mut fanin = vec![0u32; a.rows];
            let mut nnz = vec![0usize; a.rows];
            let chunk = a.rows.div_ceil(parts);
            let mut racc =
                RowAccumulator::new(b.cols, AccumPolicy::new(AccumMode::Adaptive, b.cols));
            let mut begin = 0usize;
            while begin < a.rows {
                let end = (begin + chunk).min(a.rows);
                flops_chunk(&a, &b, begin, &mut flops[begin..end]);
                fanin_chunk(&a, &b, begin, &mut fanin[begin..end]);
                symbolic_chunk(&a, &b, &mut racc, &full_flops, begin, &mut nnz[begin..end]);
                begin = end;
            }
            assert_eq!(flops, full_flops, "parts={parts}");
            assert_eq!(fanin, full_k, "parts={parts}");
            assert_eq!(nnz, full_nnz, "parts={parts}");
        }
    }

    /// Fan-in counts nonempty contributing B rows: bounded above by both
    /// `nnz(A[i,:])` and the row's FLOPs, zero exactly when FLOPs are
    /// zero, and insensitive to how heavy each contributing row is.
    #[test]
    fn fanin_counts_nonempty_contributors() {
        use crate::formats::Csr;
        // Row 0: two contributors (one B row empty → not counted).
        // Row 1: one contributor with many products (k=1, flops=3).
        // Row 2: only an empty B row → k=0, flops=0.
        // Row 3: structurally empty.
        let a = Csr::from_triplets(
            4,
            4,
            vec![(0, 0, 1.0), (0, 1, 1.0), (0, 3, 1.0), (1, 2, 1.0), (2, 3, 1.0)],
        );
        let b = Csr::from_triplets(
            4,
            8,
            vec![(0, 0, 1.0), (1, 4, 1.0), (2, 1, 1.0), (2, 5, 1.0), (2, 6, 1.0)],
        );
        let mut k = vec![0u32; a.rows];
        fanin_chunk(&a, &b, 0, &mut k);
        assert_eq!(k, vec![2, 1, 0, 0]);
        let flops = flops_per_row(&a, &b);
        for i in 0..a.rows {
            assert!(u64::from(k[i]) <= flops[i], "row {i}: fan-in bounded by FLOPs");
            assert_eq!(k[i] == 0, flops[i] == 0, "row {i}: zero together");
        }
    }

    #[test]
    fn prefix_sum_is_exclusive_and_totals() {
        assert_eq!(prefix_sum(&[]), vec![0]);
        assert_eq!(prefix_sum(&[3, 0, 2, 5]), vec![0, 3, 3, 5, 10]);
    }
}
