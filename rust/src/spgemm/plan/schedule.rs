//! Schedule pass: packing work units onto execution blocks.
//!
//! The packer is axis-free — [`schedule_loads`] sees only a load vector
//! (one estimated cost per unit), so row windows, column bands, or any
//! future unit schedule through the same code. [`schedule_windows`] is
//! the row-window adapter every existing caller uses (and the
//! coordinator re-exports, §5.1.1: windows are "scheduled to blocks in
//! random order and oversubscribed").
//!
//! Two policies are implemented and compared:
//!
//! * round-robin (the naive baseline),
//! * LPT (longest-processing-time-first greedy on the load estimates) —
//!   the oversubscription policy: light units pack onto busy blocks.

use crate::kernels::Window;

/// Assignment of work-unit index -> block index.
#[derive(Clone, Debug, PartialEq)]
pub struct Assignment {
    pub window_to_block: Vec<usize>,
    pub blocks: usize,
    /// Estimated per-block load (sum of assigned unit costs).
    pub block_load: Vec<u64>,
}

impl Assignment {
    /// Load imbalance: max/mean block load (1.0 = perfect).
    pub fn imbalance(&self) -> f64 {
        let max = *self.block_load.iter().max().unwrap_or(&0) as f64;
        let sum: u64 = self.block_load.iter().sum();
        let mean = sum as f64 / self.blocks.max(1) as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    /// Makespan estimate (max block load).
    pub fn makespan(&self) -> u64 {
        *self.block_load.iter().max().unwrap_or(&0)
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedPolicy {
    RoundRobin,
    /// Longest-processing-time-first greedy (oversubscription).
    Lpt,
}

/// Pack work units with the given estimated `loads` onto `blocks` blocks.
/// Zero-cost units are charged a floor of 1 so every unit moves the
/// balance (and `block_load` conserves the unit count on degenerate
/// all-zero inputs).
pub fn schedule_loads(loads: &[u64], blocks: usize, policy: SchedPolicy) -> Assignment {
    assert!(blocks > 0, "need at least one block");
    let mut window_to_block = vec![0usize; loads.len()];
    let mut block_load = vec![0u64; blocks];
    match policy {
        SchedPolicy::RoundRobin => {
            for (i, &cost) in loads.iter().enumerate() {
                let b = i % blocks;
                window_to_block[i] = b;
                block_load[b] += cost.max(1);
            }
        }
        SchedPolicy::Lpt => {
            // sort unit indices by descending cost, assign each to the
            // least-loaded block
            let mut order: Vec<usize> = (0..loads.len()).collect();
            order.sort_by_key(|&i| std::cmp::Reverse(loads[i]));
            for i in order {
                let (b, _) = block_load
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, l)| **l)
                    .unwrap();
                window_to_block[i] = b;
                block_load[b] += loads[i].max(1);
            }
        }
    }
    Assignment {
        window_to_block,
        blocks,
        block_load,
    }
}

/// Row-window adapter over [`schedule_loads`]: pack `windows` onto
/// `blocks` blocks by their FMA estimates.
pub fn schedule_windows(windows: &[Window], blocks: usize, policy: SchedPolicy) -> Assignment {
    let loads: Vec<u64> = windows.iter().map(|w| w.flops).collect();
    schedule_loads(&loads, blocks, policy)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The window adapter is exactly the load packer on the FMA column —
    /// both axes (row windows, column bands) schedule identically.
    #[test]
    fn window_adapter_equals_load_packer() {
        let costs = [100u64, 1, 7, 0, 90, 3];
        let ws: Vec<Window> = costs
            .iter()
            .enumerate()
            .map(|(i, &f)| Window {
                row_begin: i,
                row_end: i + 1,
                flops: f,
                out_nnz: 0,
                bins: 0,
            })
            .collect();
        for policy in [SchedPolicy::RoundRobin, SchedPolicy::Lpt] {
            assert_eq!(
                schedule_windows(&ws, 3, policy),
                schedule_loads(&costs, 3, policy),
                "{policy:?}"
            );
        }
    }
}
