//! Partition pass: slicing the product into work units along either axis.
//!
//! * **Rows** — [`partition_rows`] groups output rows into contiguous
//!   windows of roughly equal FMA volume; the LPT scheduler packs those
//!   windows onto threads. Every parallel backend partitions rows.
//! * **Columns** — [`BandSpec`] / [`BandPartition`] slice B's columns
//!   into fixed-width bands for the propagation-blocking backend
//!   (`par_gustavson_blocked`). Bounding the band width bounds the dense
//!   accumulator lane to O(band) instead of O(b.cols) — the Gu et al.
//!   propagation-blocking move (arXiv:2002.11302) that keeps the
//!   accumulator scratchpad-resident on wide hypersparse products, with
//!   SpArch-style (arXiv:2002.08947) in-order merging of the band-local
//!   partials downstream.
//!
//! A [`BandPartition`] is derived O(1) from `(b.cols, spec)` and is never
//! cached: bands are a *numeric-pass* parameter, so the symbolic plan
//! stays band-independent.

use crate::kernels::Window;

/// Group rows into contiguous windows of roughly equal FMA volume —
/// about `4 × threads` of them, so LPT can balance power-law skew by
/// packing light windows onto the thread stuck with a hub row. A window
/// is never empty; a single row heavier than the target gets its own.
/// `out_nnz`/`bins` are not used on this path and stay zero.
pub fn partition_rows(row_flops: &[u64], threads: usize) -> Vec<Window> {
    let rows = row_flops.len();
    let total: u64 = row_flops.iter().sum();
    let parts = (threads * 4).clamp(1, rows.max(1));
    let target = (total / parts as u64).max(1);
    let mut windows = Vec::with_capacity(parts + 4);
    let mut begin = 0usize;
    let mut acc = 0u64;
    for r in 0..rows {
        acc += row_flops[r];
        if acc >= target || r + 1 == rows {
            windows.push(Window {
                row_begin: begin,
                row_end: r + 1,
                flops: acc,
                out_nnz: 0,
                bins: 0,
            });
            begin = r + 1;
            acc = 0;
        }
    }
    windows
}

/// Dense-lane bytes per output column: an 8-byte accumulator value plus a
/// 1-byte presence flag (`RowAccumulator`'s `acc` + `present`).
const BAND_BYTES_PER_COL: usize = 9;

/// Scratchpad budget the auto band width targets: the band's dense lane
/// must fit in 64 KiB — the order of a per-core scratchpad/L1, and the
/// regime where the accumulator stops generating DRAM traffic.
pub const BAND_AUTO_TARGET_BYTES: usize = 1 << 16;

/// Widest power-of-two band whose dense accumulator lane
/// ([`BAND_BYTES_PER_COL`] per column) fits [`BAND_AUTO_TARGET_BYTES`],
/// clamped to `[1, b_cols]`. Deterministic in `b_cols` alone — 4096
/// columns for any product at least that wide.
pub fn auto_band_cols(b_cols: usize) -> usize {
    let budget_cols = (BAND_AUTO_TARGET_BYTES / BAND_BYTES_PER_COL).max(1);
    let mut w = 1usize;
    while w * 2 <= budget_cols {
        w *= 2;
    }
    w.min(b_cols.max(1))
}

/// How a job *asks for* a column-band width — the serializable, CLI-level
/// spelling carried on `Dataflow::ParGustavsonBlocked` and resolved to a
/// concrete width once `b.cols` is known. Bands are a plan-cache key
/// parameter in the serving layer: blocked and unblocked jobs on one
/// registered pair never share a slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BandSpec {
    /// Fixed band width in columns (clamped to `[1, b.cols]` at
    /// resolution).
    Cols(usize),
    /// The [`auto_band_cols`] scratchpad heuristic (`--band-cols auto`).
    Auto,
}

impl BandSpec {
    /// Parse a CLI spelling (`auto` or a positive column count).
    pub fn parse(s: &str) -> Option<BandSpec> {
        if s == "auto" {
            return Some(BandSpec::Auto);
        }
        s.parse::<usize>().ok().filter(|&w| w >= 1).map(BandSpec::Cols)
    }

    /// Display form: `auto` or the column count.
    pub fn describe(&self) -> String {
        match self {
            BandSpec::Cols(w) => w.to_string(),
            BandSpec::Auto => "auto".to_string(),
        }
    }

    /// Resolve to a concrete band width for a `b_cols`-wide product.
    /// Always at least 1 (degenerate zero-column products get a harmless
    /// one-column band) and never wider than the product.
    pub fn resolve(&self, b_cols: usize) -> usize {
        match self {
            BandSpec::Cols(w) => (*w).clamp(1, b_cols.max(1)),
            BandSpec::Auto => auto_band_cols(b_cols),
        }
    }
}

/// The column-band partition of one product: `total_cols` columns cut
/// into `count()` bands of `band_cols` columns each (the last band may be
/// narrower). A tiny Copy value, recomputed wherever needed — deriving it
/// is O(1), so caching it would only create staleness hazards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BandPartition {
    /// Width of every band but possibly the last, ≥ 1.
    pub band_cols: usize,
    /// Total columns partitioned (`b.cols`); zero means zero bands.
    pub total_cols: usize,
}

impl BandPartition {
    /// Partition `total_cols` columns under `spec`.
    pub fn new(spec: BandSpec, total_cols: usize) -> Self {
        Self {
            band_cols: spec.resolve(total_cols),
            total_cols,
        }
    }

    /// Number of bands (`⌈total_cols / band_cols⌉`).
    pub fn count(&self) -> usize {
        self.total_cols.div_ceil(self.band_cols)
    }

    /// The half-open column ranges `[lo, hi)` of the bands, ascending —
    /// band `k` covers `[k·w, min((k+1)·w, total_cols))`. Concatenating
    /// per-band drains in this order yields a full row in ascending
    /// column order, which is what keeps the blocked backend bitwise
    /// equal to the unblocked one.
    pub fn ranges(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        let w = self.band_cols;
        let n = self.total_cols;
        (0..self.count()).map(move |k| (k * w, ((k + 1) * w).min(n)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_rows_covers_and_conserves() {
        let flops = vec![5u64, 0, 1000, 3, 3, 3, 0, 90, 2, 1];
        let ws = partition_rows(&flops, 3);
        assert_eq!(ws.first().unwrap().row_begin, 0);
        assert_eq!(ws.last().unwrap().row_end, flops.len());
        for w in ws.windows(2) {
            assert_eq!(w[0].row_end, w[1].row_begin, "windows must tile rows");
        }
        assert!(ws.iter().all(|w| w.rows() >= 1));
        let total: u64 = ws.iter().map(|w| w.flops).sum();
        assert_eq!(total, flops.iter().sum::<u64>());
    }

    #[test]
    fn band_spec_parse_resolve_describe() {
        assert_eq!(BandSpec::parse("auto"), Some(BandSpec::Auto));
        assert_eq!(BandSpec::parse("64"), Some(BandSpec::Cols(64)));
        assert_eq!(BandSpec::parse("0"), None);
        assert_eq!(BandSpec::parse("x"), None);
        assert_eq!(BandSpec::Auto.describe(), "auto");
        assert_eq!(BandSpec::Cols(128).describe(), "128");
        // Fixed widths clamp to the product.
        assert_eq!(BandSpec::Cols(64).resolve(1 << 18), 64);
        assert_eq!(BandSpec::Cols(1 << 20).resolve(100), 100);
        assert_eq!(BandSpec::Cols(7).resolve(0), 1);
        // Auto: widest power of two under the scratchpad budget, clamped.
        let auto = BandSpec::Auto.resolve(1 << 18);
        assert_eq!(auto, 4096, "64 KiB / 9 B per col rounds down to 4096");
        assert!(auto * BAND_BYTES_PER_COL <= BAND_AUTO_TARGET_BYTES);
        assert_eq!(BandSpec::Auto.resolve(100), 100, "auto clamps to b.cols");
        assert_eq!(BandSpec::Auto.resolve(0), 1);
    }

    #[test]
    fn band_partition_tiles_columns_in_order() {
        for (spec, cols) in [
            (BandSpec::Cols(64), 1000usize),
            (BandSpec::Cols(1), 17),
            (BandSpec::Cols(17), 17),
            (BandSpec::Cols(1000), 17),
            (BandSpec::Auto, 1 << 18),
            (BandSpec::Auto, 5),
        ] {
            let p = BandPartition::new(spec, cols);
            let ranges: Vec<_> = p.ranges().collect();
            assert_eq!(ranges.len(), p.count());
            assert_eq!(ranges.first().map(|&(lo, _)| lo), Some(0));
            assert_eq!(ranges.last().map(|&(_, hi)| hi), Some(cols));
            for pair in ranges.windows(2) {
                assert_eq!(pair[0].1, pair[1].0, "bands must tile contiguously");
            }
            for &(lo, hi) in &ranges {
                assert!(hi > lo, "bands are never empty");
                assert!(hi - lo <= p.band_cols, "no band exceeds the width");
            }
        }
        // Zero columns: zero bands.
        let p = BandPartition::new(BandSpec::Auto, 0);
        assert_eq!(p.count(), 0);
        assert_eq!(p.ranges().count(), 0);
    }
}
