//! Row-partitioned parallel Gustavson SpGEMM — the CPU serving backend.
//!
//! Nagasaka et al. ("High-performance sparse matrix-matrix products on
//! Intel KNL and multicore architectures") show that row-partitioned
//! SpGEMM with per-thread accumulators is the winning multicore layout;
//! this module applies it to the Gustavson oracle:
//!
//! 1. **Symbolic pass** (§5.1.1 two-step): per-row FMA estimates drive the
//!    partition; exact per-row output sizes give every row a disjoint,
//!    pre-allocated slice of the output CSR — threads never contend.
//! 2. **LPT partition**: rows are grouped into ~4× threads contiguous
//!    windows of roughly equal FMA volume and packed onto threads with the
//!    coordinator's longest-processing-time scheduler
//!    ([`crate::coordinator::schedule_windows`]) — equal-row splits
//!    collapse on power-law inputs where a few hub rows carry most FLOPs.
//! 3. **Numeric pass**: `std::thread::scope` workers with per-thread dense
//!    accumulators write their windows' slices; output is bitwise
//!    identical to the serial [`gustavson`] oracle (same per-row
//!    accumulation order).

use super::gustavson::{flops_per_row, gustavson};
use super::Traffic;
use crate::coordinator::{schedule_windows, SchedPolicy};
use crate::formats::{Csr, Index, Value};
use crate::kernels::Window;

/// Split `rest` into consecutive disjoint mutable slices of the given
/// lengths (which must sum to at most `rest.len()`).
fn split_disjoint<'s, T>(
    mut rest: &'s mut [T],
    lens: impl Iterator<Item = usize>,
) -> Vec<&'s mut [T]> {
    let mut out = Vec::new();
    for len in lens {
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(len);
        out.push(head);
        rest = tail;
    }
    out
}

/// Group rows into contiguous windows of roughly equal FMA volume —
/// about `4 × threads` of them, so LPT can balance power-law skew by
/// packing light windows onto the thread stuck with a hub row. A window
/// is never empty; a single row heavier than the target gets its own.
/// `out_nnz`/`bins` are not used on this path and stay zero.
fn partition_rows(row_flops: &[u64], threads: usize) -> Vec<Window> {
    let rows = row_flops.len();
    let total: u64 = row_flops.iter().sum();
    let parts = (threads * 4).clamp(1, rows.max(1));
    let target = (total / parts as u64).max(1);
    let mut windows = Vec::with_capacity(parts + 4);
    let mut begin = 0usize;
    let mut acc = 0u64;
    for r in 0..rows {
        acc += row_flops[r];
        if acc >= target || r + 1 == rows {
            windows.push(Window {
                row_begin: begin,
                row_end: r + 1,
                flops: acc,
                out_nnz: 0,
                bins: 0,
            });
            begin = r + 1;
            acc = 0;
        }
    }
    windows
}

/// Parallel Gustavson SpGEMM over `threads` OS threads. Returns the
/// canonical (sorted, merged) CSR product — bitwise identical to
/// [`gustavson`] — and the summed traffic profile.
pub fn par_gustavson(a: &Csr, b: &Csr, threads: usize) -> (Csr, Traffic) {
    assert_eq!(a.cols, b.rows, "dimension mismatch");
    let threads = threads.max(1);
    if threads == 1 || a.rows == 0 || b.cols == 0 {
        return gustavson(a, b);
    }

    let row_flops = flops_per_row(a, b);
    let windows = partition_rows(&row_flops, threads);
    let assignment = schedule_windows(&windows, threads, SchedPolicy::Lpt);
    let owner = |wi: usize| assignment.window_to_block[wi];

    // ---- Symbolic phase (parallel): exact nnz of every output row.
    let mut row_nnz = vec![0usize; a.rows];
    {
        let slices = split_disjoint(row_nnz.as_mut_slice(), windows.iter().map(|w| w.rows()));
        let mut work: Vec<Vec<(usize, &mut [usize])>> = (0..threads).map(|_| Vec::new()).collect();
        for (wi, sl) in slices.into_iter().enumerate() {
            work[owner(wi)].push((wi, sl));
        }
        let windows = &windows;
        std::thread::scope(|scope| {
            for chunk in work {
                if chunk.is_empty() {
                    continue;
                }
                scope.spawn(move || {
                    // visited-stamp array, tagged by (globally unique) row
                    let mut stamp = vec![u32::MAX; b.cols];
                    for (wi, out) in chunk {
                        let w = &windows[wi];
                        for (off, i) in (w.row_begin..w.row_end).enumerate() {
                            let tag = i as u32;
                            let (acols, _) = a.row(i);
                            let mut count = 0usize;
                            for &k in acols {
                                let (bcols, _) = b.row(k as usize);
                                for &j in bcols {
                                    if stamp[j as usize] != tag {
                                        stamp[j as usize] = tag;
                                        count += 1;
                                    }
                                }
                            }
                            out[off] = count;
                        }
                    }
                });
            }
        });
    }

    let mut row_ptr = Vec::with_capacity(a.rows + 1);
    row_ptr.push(0usize);
    for &n in &row_nnz {
        row_ptr.push(row_ptr.last().unwrap() + n);
    }
    let nnz_total = row_ptr[a.rows];
    let mut col_idx = vec![0 as Index; nnz_total];
    let mut data = vec![0.0 as Value; nnz_total];

    // ---- Numeric phase (parallel): disjoint output slices per window.
    let traffics: Vec<Traffic> = {
        let window_len = |w: &Window| row_ptr[w.row_end] - row_ptr[w.row_begin];
        let col_slices = split_disjoint(col_idx.as_mut_slice(), windows.iter().map(window_len));
        let data_slices = split_disjoint(data.as_mut_slice(), windows.iter().map(window_len));
        let mut work: Vec<Vec<(usize, &mut [Index], &mut [Value])>> =
            (0..threads).map(|_| Vec::new()).collect();
        for (wi, (cs, ds)) in col_slices.into_iter().zip(data_slices).enumerate() {
            work[owner(wi)].push((wi, cs, ds));
        }
        let windows = &windows;
        let row_ptr = &row_ptr;
        std::thread::scope(|scope| {
            let handles: Vec<_> = work
                .into_iter()
                .filter(|chunk| !chunk.is_empty())
                .map(|chunk| {
                    scope.spawn(move || {
                        let mut t = Traffic::default();
                        let mut acc = vec![0.0 as Value; b.cols];
                        let mut present = vec![false; b.cols];
                        let mut touched: Vec<Index> = Vec::with_capacity(256);
                        for (wi, cols_out, data_out) in chunk {
                            let w = &windows[wi];
                            let base = row_ptr[w.row_begin];
                            for i in w.row_begin..w.row_end {
                                let (acols, avals) = a.row(i);
                                for (&k, &av) in acols.iter().zip(avals) {
                                    t.a_reads += 1;
                                    let (bcols, bvals) = b.row(k as usize);
                                    t.b_reads += bcols.len() as u64;
                                    for (&j, &bv) in bcols.iter().zip(bvals) {
                                        let ju = j as usize;
                                        if !present[ju] {
                                            present[ju] = true;
                                            touched.push(j);
                                        }
                                        acc[ju] += av * bv;
                                        t.flops += 1;
                                    }
                                }
                                touched.sort_unstable();
                                let lo = row_ptr[i] - base;
                                for (slot, &j) in touched.iter().enumerate() {
                                    cols_out[lo + slot] = j;
                                    data_out[lo + slot] = acc[j as usize];
                                    acc[j as usize] = 0.0;
                                    present[j as usize] = false;
                                    t.c_writes += 1;
                                }
                                touched.clear();
                            }
                        }
                        t
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("par_gustavson worker panicked"))
                .collect()
        })
    };

    let mut t = Traffic::default();
    for p in traffics {
        t.a_reads += p.a_reads;
        t.b_reads += p.b_reads;
        t.c_writes += p.c_writes;
        t.flops += p.flops;
    }

    let c = Csr {
        rows: a.rows,
        cols: b.cols,
        row_ptr,
        col_idx,
        data,
    };
    debug_assert!(c.validate().is_ok());
    (c, t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{erdos_renyi, rmat, RmatParams};

    #[test]
    fn partition_covers_rows_and_conserves_flops() {
        let flops = vec![5u64, 0, 1000, 3, 3, 3, 0, 90, 2, 1];
        let ws = partition_rows(&flops, 3);
        assert_eq!(ws.first().unwrap().row_begin, 0);
        assert_eq!(ws.last().unwrap().row_end, flops.len());
        for w in ws.windows(2) {
            assert_eq!(w[0].row_end, w[1].row_begin, "windows must tile rows");
        }
        assert!(ws.iter().all(|w| w.rows() >= 1));
        let total: u64 = ws.iter().map(|w| w.flops).sum();
        assert_eq!(total, flops.iter().sum::<u64>());
    }

    #[test]
    fn matches_serial_bitwise_across_thread_counts() {
        let a = rmat(&RmatParams::new(8, 3000, 5));
        let b = rmat(&RmatParams::new(8, 3000, 6));
        let (c1, t1) = gustavson(&a, &b);
        for threads in [1, 2, 3, 4, 7] {
            let (cp, tp) = par_gustavson(&a, &b, threads);
            // Same accumulation order per row -> bitwise equality, not
            // just approx_same.
            assert_eq!(c1.row_ptr, cp.row_ptr, "threads={threads}");
            assert_eq!(c1.col_idx, cp.col_idx, "threads={threads}");
            assert_eq!(c1.data, cp.data, "threads={threads}");
            assert_eq!(t1.flops, tp.flops, "threads={threads}");
            assert_eq!(t1.a_reads, tp.a_reads, "threads={threads}");
            assert_eq!(t1.b_reads, tp.b_reads, "threads={threads}");
            assert_eq!(t1.c_writes, tp.c_writes, "threads={threads}");
        }
    }

    #[test]
    fn degenerate_shapes() {
        let z = Csr::zero(6, 6);
        let (c, t) = par_gustavson(&z, &z, 4);
        assert_eq!(c.nnz(), 0);
        assert_eq!(t.flops, 0);
        let i = Csr::identity(17);
        let a = erdos_renyi(17, 60, 3);
        let (c, _) = par_gustavson(&a, &i, 3);
        assert!(c.approx_same(&a));
        // more threads than rows
        let tiny = erdos_renyi(2, 3, 9);
        let (c, _) = par_gustavson(&tiny, &tiny, 16);
        let (oracle, _) = gustavson(&tiny, &tiny);
        assert!(c.approx_same(&oracle));
    }

    /// The acceptance bar: on an R-MAT scale-13 input, 4 threads must (a)
    /// match the serial oracle exactly and (b) beat it in wall-clock.
    /// The timing half is skipped on machines without real parallelism.
    #[test]
    fn par4_beats_serial_on_rmat_scale13() {
        let a = rmat(&RmatParams::new(13, 260_000, 1));
        let b = rmat(&RmatParams::new(13, 260_000, 2));
        let (c1, _) = gustavson(&a, &b);
        let (c4, _) = par_gustavson(&a, &b, 4);
        assert_eq!(c1.row_ptr, c4.row_ptr);
        assert_eq!(c1.col_idx, c4.col_idx);
        assert_eq!(c1.data, c4.data, "par output must match the oracle exactly");

        // The timing half needs real parallelism: on fewer than 4 cores (or
        // a loaded shared runner) 4 oversubscribed threads can lose to
        // serial without any code defect. SMASH_SKIP_TIMING=1 force-skips.
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        if cores < 4 || std::env::var("SMASH_SKIP_TIMING").is_ok() {
            eprintln!("skipping wall-clock assertion: {cores} core(s) available");
            return;
        }
        let best_of = |f: &dyn Fn() -> (Csr, Traffic)| {
            (0..3)
                .map(|_| {
                    let t0 = std::time::Instant::now();
                    std::hint::black_box(f());
                    t0.elapsed()
                })
                .min()
                .unwrap()
        };
        // Sibling tests run concurrently in the same binary and can steal
        // cores mid-sample; retry once so a transient squeeze on the par
        // samples does not fail the build.
        for attempt in 0..2 {
            let serial = best_of(&|| gustavson(&a, &b));
            let par = best_of(&|| par_gustavson(&a, &b, 4));
            if par < serial {
                return;
            }
            if attempt == 1 {
                panic!("par_gustavson(4) took {par:?}, serial gustavson {serial:?}");
            }
            eprintln!("timing inverted ({par:?} vs {serial:?}); retrying once");
        }
    }
}
