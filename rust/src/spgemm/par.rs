//! Row-partitioned parallel Gustavson SpGEMM — the CPU serving backend.
//!
//! Nagasaka et al. ("High-performance sparse matrix-matrix products on
//! Intel KNL and multicore architectures") show that row-partitioned
//! SpGEMM with per-thread accumulators is the winning multicore layout;
//! this module applies it to the Gustavson oracle:
//!
//! 1. **FLOP pass** (parallel): per-row FMA estimates
//!    (`flops_of_row`, chunked over the pool) drive the partition.
//! 2. **Symbolic pass** (§5.1.1 two-step, parallel): exact per-row output
//!    sizes give every row a disjoint, pre-allocated slice of the output
//!    CSR — threads never contend. The per-row distinct-count step is the
//!    shared [`super::RowAccumulator::symbolic_row`] the serial oracle
//!    uses too.
//! 3. **Prefix sum** (parallel two-pass scan): per-chunk sums, a serial
//!    scan over the handful of chunk offsets, then parallel local scans —
//!    exact, so the result is identical to the serial scan.
//! 4. **LPT partition**: rows are grouped into ~4× threads contiguous
//!    windows of roughly equal FMA volume and packed onto threads with the
//!    coordinator's longest-processing-time scheduler
//!    ([`crate::coordinator::schedule_windows`]) — equal-row splits
//!    collapse on power-law inputs where a few hub rows carry most FLOPs.
//! 5. **Numeric pass** (parallel): per-thread hybrid accumulators
//!    ([`super::RowAccumulator`] — hash lane for light rows, dense lane
//!    for heavy rows, chosen per row from the FLOPs upper bound) write
//!    their windows' slices; output is bitwise identical to the serial
//!    [`gustavson`] oracle (same per-row, per-column accumulation order
//!    in every lane). On hypersparse inputs a worker's scratch is O(live
//!    row nnz), not O(b.cols).
//!
//! Steps 1–3 are captured in a reusable [`SymbolicPlan`] so the serving
//! coordinator can amortize one symbolic pass across a batch of jobs that
//! share operands ([`par_gustavson_with_plan`]).
//!
//! The numeric pass is generic over a [`Semiring`]: the same pipeline
//! serves boolean reachability, min-plus shortest-path, and max-times
//! reliability products ([`par_gustavson_semiring`] /
//! [`par_gustavson_kind`]). Steps 1–3 never read values, so a
//! `SymbolicPlan` is semiring-invariant — one cached plan serves a
//! mixed-semiring burst against the same operand pair.
//!
//! ## The persistent worker pool
//!
//! All parallel phases execute on a process-wide [`WorkerPool`] of
//! long-lived `std::thread` workers fed over channels — a serving burst of
//! small products no longer pays thread spawn/join per call.
//! [`par_gustavson_spawning`] keeps the old spawn-per-call execution as a
//! benchmark baseline.

use super::accumulator::{AccumMode, AccumPolicy, AccumSpec, RowAccumulator};
use super::gustavson::gustavson;
use super::plan::{partition_rows, rank, schedule_windows, BandPartition, BandSpec, SchedPolicy};
use super::semiring::{Arithmetic, Boolean, MaxTimes, MinPlus, Semiring, SemiringKind};
use super::{BandStats, Traffic};
use crate::formats::{Csr, Index, Value};
use crate::kernels::Window;

pub use super::plan::SymbolicPlan;
use crate::faults::{self, FaultSite};
use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// A scoped task with its lifetime erased, plus the completion channel of
/// the scope that submitted it. `index` is the task's position in its
/// scope's submission order, echoed back on the done channel so a panic
/// can be attributed to a specific task.
struct PoolJob {
    index: usize,
    task: Box<dyn FnOnce() + Send + 'static>,
    done: Sender<(usize, std::thread::Result<()>)>,
}

/// One quarantined task panic from [`WorkerPool::try_scope`]: which task
/// of the scope died, and its stringified panic payload. The worker that
/// ran it already caught the unwind and went back to its queue — the pool
/// stays serviceable.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TaskPanic {
    /// Index of the task in the scope's submission order.
    pub task: usize,
    /// The panic payload rendered as text (`&str`/`String` payloads
    /// verbatim, anything else a placeholder).
    pub message: String,
}

/// Render a panic payload as text: the common `&'static str` / `String`
/// payloads verbatim, anything else a placeholder.
pub fn panic_message(payload: &(dyn Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

/// Why a checked parallel numeric pass did not produce a result — the
/// typed form of the two ways a job dies mid-kernel. Converted by the
/// coordinator into `ServeError::WorkerPanicked` / `DeadlineExceeded`
/// on the failed `Response`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ParError {
    /// One or more pool tasks panicked (quarantined, in submission
    /// order). The partial output was discarded.
    Panicked(Vec<TaskPanic>),
    /// The job's deadline expired at a kernel checkpoint; remaining rows
    /// were abandoned and the partial output discarded.
    DeadlineExceeded,
}

/// A persistent pool of worker threads fed over an MPSC channel.
///
/// Workers are long-lived: they are spawned once (lazily, growing on
/// demand) and then sit in `recv()` between bursts, so a stream of small
/// parallel products pays channel sends instead of thread spawn/join per
/// call. [`WorkerPool::scope`] provides scoped execution — borrowed data
/// is safe because the call blocks until every submitted task has
/// signalled completion (workers signal even when a task panics).
///
/// The process-wide instance behind [`par_gustavson`] is
/// [`WorkerPool::global`].
pub struct WorkerPool {
    /// Submission side. Wrapped in a `Mutex` so `&self` sends are possible
    /// on toolchains where `mpsc::Sender` is not `Sync`.
    tx: Mutex<Sender<PoolJob>>,
    /// Shared receive side all workers pull from.
    queue: Arc<Mutex<Receiver<PoolJob>>>,
    /// Number of worker threads spawned so far.
    spawned: Mutex<usize>,
}

impl WorkerPool {
    /// Create a pool and spawn `workers.max(1)` worker threads.
    pub fn new(workers: usize) -> Self {
        let (tx, rx) = channel();
        let pool = Self {
            tx: Mutex::new(tx),
            queue: Arc::new(Mutex::new(rx)),
            spawned: Mutex::new(0),
        };
        pool.ensure_workers(workers.max(1));
        pool
    }

    /// The process-wide pool used by [`par_gustavson`], created on first
    /// use with one worker per available core and grown on demand.
    pub fn global() -> &'static WorkerPool {
        static POOL: OnceLock<WorkerPool> = OnceLock::new();
        POOL.get_or_init(|| {
            let cores = std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(2);
            WorkerPool::new(cores)
        })
    }

    /// Number of live worker threads.
    pub fn workers(&self) -> usize {
        *self.spawned.lock().unwrap()
    }

    /// Grow the pool to at least `n` workers (never shrinks).
    pub fn ensure_workers(&self, n: usize) {
        let mut spawned = self.spawned.lock().unwrap();
        while *spawned < n {
            let queue = Arc::clone(&self.queue);
            std::thread::Builder::new()
                .name(format!("smash-pool-{}", *spawned))
                .spawn(move || worker_loop(queue))
                .expect("failed to spawn pool worker");
            *spawned += 1;
        }
    }

    /// Run every task to completion on the pool, blocking the caller until
    /// all have finished. If any task panicked, one captured payload is
    /// re-raised here (after all tasks finished — workers survive task
    /// panics). Tasks may borrow caller data: the blocking wait is what
    /// makes the lifetime erasure below sound.
    ///
    /// Tasks must not themselves call `scope` on the same pool — with all
    /// workers busy, nested waits could deadlock.
    pub fn scope<'env>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'env>>) {
        let mut panics = self.scope_impl(tasks);
        if let Some((_, payload)) = panics.pop() {
            resume_unwind(payload);
        }
    }

    /// [`scope`](WorkerPool::scope) with panic *quarantine*: task panics
    /// are caught on the workers, collected, and returned as typed
    /// per-task errors instead of unwinding into the caller. Like `scope`
    /// this blocks until every task has signalled completion, so the
    /// borrowed-data guarantee is identical — and the workers that ran
    /// panicking tasks are already back on the queue when this returns.
    /// Errors are sorted by task index (submission order).
    pub fn try_scope<'env>(
        &self,
        tasks: Vec<Box<dyn FnOnce() + Send + 'env>>,
    ) -> Result<(), Vec<TaskPanic>> {
        let panics = self.scope_impl(tasks);
        if panics.is_empty() {
            return Ok(());
        }
        let mut out: Vec<TaskPanic> = panics
            .iter()
            .map(|(task, payload)| TaskPanic {
                task: *task,
                message: panic_message(payload.as_ref()),
            })
            .collect();
        out.sort_by_key(|p| p.task);
        Err(out)
    }

    /// Shared engine of `scope`/`try_scope`: run all tasks, block for all
    /// completions, return every captured panic as `(task index, payload)`.
    fn scope_impl<'env>(
        &self,
        tasks: Vec<Box<dyn FnOnce() + Send + 'env>>,
    ) -> Vec<(usize, Box<dyn Any + Send>)> {
        let n = tasks.len();
        if n == 0 {
            return Vec::new();
        }
        self.ensure_workers(n.min(64));
        let (done_tx, done_rx) = channel();
        {
            let tx = self.tx.lock().unwrap();
            for (index, task) in tasks.into_iter().enumerate() {
                // SAFETY: the loop below blocks until every task has sent
                // its completion message (sent even on panic, via
                // catch_unwind in the worker), so all borrows inside
                // `task` strictly outlive its execution.
                let task: Box<dyn FnOnce() + Send + 'static> =
                    unsafe { std::mem::transmute(task) };
                tx.send(PoolJob {
                    index,
                    task,
                    done: done_tx.clone(),
                })
                .expect("worker pool queue closed");
            }
        }
        drop(done_tx);
        let mut panics = Vec::new();
        for _ in 0..n {
            let (index, result) = done_rx.recv().expect("worker pool hung up mid-scope");
            if let Err(payload) = result {
                panics.push((index, payload));
            }
        }
        panics
    }
}

fn worker_loop(queue: Arc<Mutex<Receiver<PoolJob>>>) {
    loop {
        let job = {
            let guard = queue.lock().unwrap();
            guard.recv()
        };
        match job {
            Ok(PoolJob { index, task, done }) => {
                let result = catch_unwind(AssertUnwindSafe(move || task()));
                let _ = done.send((index, result));
            }
            // Channel closed: the owning pool was dropped.
            Err(_) => break,
        }
    }
}

/// How a parallel phase executes its task set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Exec {
    /// On the persistent [`WorkerPool::global`] (the default).
    Pool,
    /// Spawn-per-call via `std::thread::scope` (PR-1 behaviour, kept as
    /// the benchmark baseline).
    Spawn,
}

/// Run a set of scoped tasks under the chosen execution mode.
fn run_scoped<'env>(tasks: Vec<Box<dyn FnOnce() + Send + 'env>>, exec: Exec) {
    match exec {
        Exec::Pool => WorkerPool::global().scope(tasks),
        Exec::Spawn => {
            std::thread::scope(|s| {
                for task in tasks {
                    s.spawn(task);
                }
            });
        }
    }
}

/// [`run_scoped`] with panic quarantine: task panics come back as typed
/// [`TaskPanic`]s (in submission order) instead of unwinding.
fn run_scoped_try<'env>(
    tasks: Vec<Box<dyn FnOnce() + Send + 'env>>,
    exec: Exec,
) -> Result<(), Vec<TaskPanic>> {
    match exec {
        Exec::Pool => WorkerPool::global().try_scope(tasks),
        Exec::Spawn => {
            let mut panics = Vec::new();
            std::thread::scope(|s| {
                let handles: Vec<_> = tasks.into_iter().map(|task| s.spawn(task)).collect();
                for (task, handle) in handles.into_iter().enumerate() {
                    if let Err(payload) = handle.join() {
                        panics.push(TaskPanic {
                            task,
                            message: panic_message(payload.as_ref()),
                        });
                    }
                }
            });
            if panics.is_empty() {
                Ok(())
            } else {
                Err(panics)
            }
        }
    }
}

/// Split `rest` into consecutive disjoint mutable slices of the given
/// lengths (which must sum to at most `rest.len()`).
fn split_disjoint<'s, T>(
    mut rest: &'s mut [T],
    lens: impl Iterator<Item = usize>,
) -> Vec<&'s mut [T]> {
    let mut out = Vec::new();
    for len in lens {
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(len);
        out.push(head);
        rest = tail;
    }
    out
}

/// Split `n` items into at most `parts` contiguous `(begin, end)` chunks
/// of near-equal length (the first `n % parts` chunks get one extra).
/// Always returns at least one (possibly empty) chunk.
fn even_chunks(n: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.clamp(1, n.max(1));
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut begin = 0usize;
    for c in 0..parts {
        let len = base + usize::from(c < extra);
        out.push((begin, begin + len));
        begin += len;
    }
    out
}

/// Below this row count the parallel FLOP pass is not worth the task
/// plumbing; the serial loop runs instead (results are identical).
const PAR_FLOPS_MIN_ROWS: usize = 1 << 10;
/// Below this row count the prefix sum stays serial: it is O(rows)
/// integer adds, so the two pool dispatches of the parallel scan only
/// pay for themselves on large row counts.
const PAR_SCAN_MIN_ROWS: usize = 1 << 16;

/// Compute the full symbolic plan of C = A·B (FLOP counts, exact per-row
/// output sizes, row pointers) with up to `threads`-way parallelism on
/// the persistent pool — the parallel driver of the plan pipeline
/// ([`super::plan`]): the same rank-pass kernels the serial reference
/// composition runs, chunked over the pool, with the partition and
/// schedule passes deciding the chunking. The result is independent of
/// `threads` *and* of the accumulator policy — only the chunking and
/// scratch shape vary — so plans are safely shareable across jobs that
/// request different thread counts, accumulator modes, or thresholds;
/// it is also field-for-field identical to
/// [`symbolic_plan_serial`](super::plan::symbolic_plan_serial).
pub fn symbolic_plan(a: &Csr, b: &Csr, threads: usize) -> SymbolicPlan {
    symbolic_plan_exec(a, b, threads.max(1), Exec::Pool, AccumSpec::default())
}

fn symbolic_plan_exec(
    a: &Csr,
    b: &Csr,
    threads: usize,
    exec: Exec,
    spec: AccumSpec,
) -> SymbolicPlan {
    assert_eq!(a.cols, b.rows, "dimension mismatch");
    // Fault site `symbolic`: a panic here dies on the *calling* thread —
    // inside the coordinator's plan-cache build, exercising slot
    // poisoning rather than pool quarantine.
    faults::hit(FaultSite::Symbolic, None);
    let rows = a.rows;

    // ---- Rank pass, FLOPs statistic: chunked evenly by row count over
    // the same `rank::flops_chunk` kernel the serial pipeline runs.
    let mut row_flops = vec![0u64; rows];
    if threads == 1 || rows < PAR_FLOPS_MIN_ROWS {
        rank::flops_chunk(a, b, 0, &mut row_flops);
    } else {
        let chunks = even_chunks(rows, threads);
        let slices = split_disjoint(row_flops.as_mut_slice(), chunks.iter().map(|&(s, e)| e - s));
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = chunks
            .iter()
            .zip(slices)
            .map(|(&(begin, _), out)| {
                Box::new(move || {
                    rank::flops_chunk(a, b, begin, out);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        run_scoped(tasks, exec);
    }

    // ---- Rank pass, merge fan-in statistic: same even-by-row-count
    // chunking as the FLOPs pass, same `rank::fanin_chunk` kernel as the
    // serial pipeline — integer counts, so chunking cannot change them.
    let mut row_k = vec![0u32; rows];
    if threads == 1 || rows < PAR_FLOPS_MIN_ROWS {
        rank::fanin_chunk(a, b, 0, &mut row_k);
    } else {
        let chunks = even_chunks(rows, threads);
        let slices = split_disjoint(row_k.as_mut_slice(), chunks.iter().map(|&(s, e)| e - s));
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = chunks
            .iter()
            .zip(slices)
            .map(|(&(begin, _), out)| {
                Box::new(move || {
                    rank::fanin_chunk(a, b, begin, out);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        run_scoped(tasks, exec);
    }

    // The FLOPs distribution is known now, so even AccumSpec::Auto can
    // resolve before the symbolic pass. Lane choice here affects only
    // scratch shape and stats, never the counted nnz — plans stay
    // policy-independent.
    let policy = spec.resolve(b.cols, &row_flops);

    // ---- Rank pass, exact-nnz statistic: the partition pass cuts row
    // windows by FMA volume (the same windows the numeric pass will use)
    // and the schedule pass packs them, so a hub row does not serialize
    // one accumulator. Each worker runs the serial pipeline's
    // `rank::symbolic_chunk` kernel; its accumulator picks the
    // stamp-array or hash lane per row from the FLOPs bound — under the
    // adaptive policy a hash-only chunk never allocates O(b.cols)
    // scratch.
    let windows = partition_rows(&row_flops, threads);
    let assignment = schedule_windows(&windows, threads, SchedPolicy::Lpt);
    let mut row_nnz = vec![0usize; rows];
    {
        let slices = split_disjoint(row_nnz.as_mut_slice(), windows.iter().map(|w| w.rows()));
        let mut work: Vec<Vec<(usize, &mut [usize])>> = (0..threads).map(|_| Vec::new()).collect();
        for (wi, sl) in slices.into_iter().enumerate() {
            work[assignment.window_to_block[wi]].push((wi, sl));
        }
        let windows = &windows;
        let row_flops = &row_flops;
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = work
            .into_iter()
            .filter(|chunk| !chunk.is_empty())
            .map(|chunk| {
                Box::new(move || {
                    let mut racc = RowAccumulator::new(b.cols, policy);
                    for (wi, out) in chunk {
                        let w = &windows[wi];
                        rank::symbolic_chunk(a, b, &mut racc, row_flops, w.row_begin, out);
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        run_scoped(tasks, exec);
    }

    // ---- Prefix sum -> row pointers. Parallel two-pass scan past the
    // serial-grain threshold: per-chunk sums, serial scan over the few
    // chunk offsets, parallel local scans. Integer addition is exact, so
    // this is identical to the serial pipeline's `rank::prefix_sum`.
    let mut row_ptr;
    if threads == 1 || rows < PAR_SCAN_MIN_ROWS {
        row_ptr = rank::prefix_sum(&row_nnz);
    } else {
        row_ptr = vec![0usize; rows + 1];
        let chunks = even_chunks(rows, threads);
        let mut sums = vec![0usize; chunks.len()];
        {
            let row_nnz = &row_nnz;
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = chunks
                .iter()
                .zip(sums.iter_mut())
                .map(|(&(s, e), slot)| {
                    Box::new(move || {
                        *slot = row_nnz[s..e].iter().sum();
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            run_scoped(tasks, exec);
        }
        let mut offsets = Vec::with_capacity(chunks.len());
        let mut acc = 0usize;
        for &s in &sums {
            offsets.push(acc);
            acc += s;
        }
        {
            let slices = split_disjoint(&mut row_ptr[1..], chunks.iter().map(|&(s, e)| e - s));
            let row_nnz = &row_nnz;
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = chunks
                .iter()
                .zip(slices)
                .zip(offsets)
                .map(|((&(s, _), out), offset)| {
                    Box::new(move || {
                        let mut run = offset;
                        for (off, slot) in out.iter_mut().enumerate() {
                            run += row_nnz[s + off];
                            *slot = run;
                        }
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            run_scoped(tasks, exec);
        }
    }

    SymbolicPlan {
        row_flops,
        row_k,
        row_nnz,
        row_ptr,
    }
}

/// Numeric phase against a precomputed [`SymbolicPlan`] (which must come
/// from the same A·B pair — checked by shape assertions and a debug
/// validation of the result). Used by the coordinator to amortize one
/// symbolic pass across a batch of jobs sharing registered operands;
/// output is bitwise identical to [`gustavson`]. Runs the adaptive
/// accumulator policy; see [`par_gustavson_with_plan_accum`] to force a
/// lane.
pub fn par_gustavson_with_plan(
    a: &Csr,
    b: &Csr,
    threads: usize,
    plan: &SymbolicPlan,
) -> (Csr, Traffic) {
    par_gustavson_with_plan_accum(a, b, threads, plan, AccumMode::Adaptive)
}

/// [`par_gustavson_with_plan`] with an explicit accumulator mode. Plans
/// are mode-independent, so one cached plan serves adaptive, forced-dense
/// and forced-hash jobs alike.
pub fn par_gustavson_with_plan_accum(
    a: &Csr,
    b: &Csr,
    threads: usize,
    plan: &SymbolicPlan,
    accum: AccumMode,
) -> (Csr, Traffic) {
    par_gustavson_with_plan_policy(a, b, threads, plan, AccumPolicy::new(accum, b.cols))
}

/// [`par_gustavson_with_plan`] with a fully resolved [`AccumPolicy`] —
/// mode *and* threshold. The per-job tuning surface: the `tune` sweep
/// driver and the coordinator's per-job `AccumSpec` resolution both land
/// here. Plans are policy-independent, so one cached plan serves every
/// swept threshold.
pub fn par_gustavson_with_plan_policy(
    a: &Csr,
    b: &Csr,
    threads: usize,
    plan: &SymbolicPlan,
    policy: AccumPolicy,
) -> (Csr, Traffic) {
    par_gustavson_with_plan_semiring(a, b, threads, plan, policy, Arithmetic)
}

/// [`par_gustavson_with_plan_policy`] over an arbitrary [`Semiring`] —
/// the semiring-generic serving hot path. The plan is *semiring-invariant*
/// (the symbolic pass never reads values and the output is structural),
/// so one cached plan serves arithmetic, boolean, min-plus, and max-times
/// jobs against the same operand pair alike; only the numeric fold
/// changes. Output is bitwise identical to the serial
/// [`spgemm_semiring`](super::spgemm_semiring) oracle.
pub fn par_gustavson_with_plan_semiring<S: Semiring>(
    a: &Csr,
    b: &Csr,
    threads: usize,
    plan: &SymbolicPlan,
    policy: AccumPolicy,
    semiring: S,
) -> (Csr, Traffic) {
    assert_eq!(a.cols, b.rows, "dimension mismatch");
    assert_eq!(plan.row_ptr.len(), a.rows + 1, "plan is for a different A");
    numeric_with_plan(a, b, threads.max(1), plan, Exec::Pool, policy, semiring)
}

/// [`par_gustavson_with_plan_semiring`] dispatched from a runtime
/// [`SemiringKind`] — what the coordinator calls. The match hands each
/// kind to its *monomorphized* kernel, so an arithmetic serving job pays
/// no per-FLOP dispatch for the generalization.
pub fn par_gustavson_with_plan_kind(
    a: &Csr,
    b: &Csr,
    threads: usize,
    plan: &SymbolicPlan,
    policy: AccumPolicy,
    kind: SemiringKind,
) -> (Csr, Traffic) {
    match kind {
        SemiringKind::Arithmetic => {
            par_gustavson_with_plan_semiring(a, b, threads, plan, policy, Arithmetic)
        }
        SemiringKind::Boolean => {
            par_gustavson_with_plan_semiring(a, b, threads, plan, policy, Boolean)
        }
        SemiringKind::MinPlus => {
            par_gustavson_with_plan_semiring(a, b, threads, plan, policy, MinPlus)
        }
        SemiringKind::MaxTimes => {
            par_gustavson_with_plan_semiring(a, b, threads, plan, policy, MaxTimes)
        }
    }
}

/// [`par_gustavson_with_plan_kind`] with full fault containment — the
/// coordinator's checked hot path. A pool-task panic comes back as
/// [`ParError::Panicked`] (quarantined per task, pool still serviceable);
/// a `deadline` in the past — at entry, or crossed at a per-window
/// checkpoint mid-numeric — abandons the remaining rows and returns
/// [`ParError::DeadlineExceeded`] instead of serving a late result. With
/// `deadline: None` and no injected faults this is byte-for-byte the
/// uncheck path's work: same windows, same accumulators, bitwise-equal
/// output.
pub fn par_gustavson_with_plan_checked(
    a: &Csr,
    b: &Csr,
    threads: usize,
    plan: &SymbolicPlan,
    policy: AccumPolicy,
    kind: SemiringKind,
    deadline: Option<Instant>,
) -> Result<(Csr, Traffic), ParError> {
    assert_eq!(a.cols, b.rows, "dimension mismatch");
    assert_eq!(plan.row_ptr.len(), a.rows + 1, "plan is for a different A");
    let threads = threads.max(1);
    match kind {
        SemiringKind::Arithmetic => {
            numeric_with_plan_checked(a, b, threads, plan, Exec::Pool, policy, Arithmetic, deadline)
        }
        SemiringKind::Boolean => {
            numeric_with_plan_checked(a, b, threads, plan, Exec::Pool, policy, Boolean, deadline)
        }
        SemiringKind::MinPlus => {
            numeric_with_plan_checked(a, b, threads, plan, Exec::Pool, policy, MinPlus, deadline)
        }
        SemiringKind::MaxTimes => {
            numeric_with_plan_checked(a, b, threads, plan, Exec::Pool, policy, MaxTimes, deadline)
        }
    }
}

/// Infallible wrapper around the checked numeric core, preserving the
/// historical contract of the plan-backed entry points: no deadline, and
/// a task panic re-raised on the calling thread.
fn numeric_with_plan<S: Semiring>(
    a: &Csr,
    b: &Csr,
    threads: usize,
    plan: &SymbolicPlan,
    exec: Exec,
    policy: AccumPolicy,
    semiring: S,
) -> (Csr, Traffic) {
    match numeric_with_plan_checked(a, b, threads, plan, exec, policy, semiring, None) {
        Ok(r) => r,
        Err(ParError::Panicked(panics)) => {
            let p = &panics[0];
            panic!("worker task {} panicked: {}", p.task, p.message);
        }
        Err(ParError::DeadlineExceeded) => unreachable!("no deadline was set"),
    }
}

/// Deadline rows between `Instant::now()` polls: expiry is detected via a
/// shared flag every row, but the clock itself is only read once per this
/// many rows per worker, so the checkpoint cost stays off the row loop.
const DEADLINE_POLL_ROWS: u32 = 64;

fn numeric_with_plan_checked<S: Semiring>(
    a: &Csr,
    b: &Csr,
    threads: usize,
    plan: &SymbolicPlan,
    exec: Exec,
    policy: AccumPolicy,
    semiring: S,
    deadline: Option<Instant>,
) -> Result<(Csr, Traffic), ParError> {
    // Fault site `schedule`: the seam between the (possibly cached)
    // symbolic plan and the numeric pass — a panic here dies on the
    // calling thread, before any window is packed.
    faults::hit(FaultSite::Schedule, None);
    // Recomputed per call even with a cached plan: the partition is
    // O(rows) and LPT packs ~4×threads windows — noise next to the
    // O(flops) numeric pass, and it keeps plans thread-count independent.
    let windows = partition_rows(&plan.row_flops, threads);
    let assignment = schedule_windows(&windows, threads, SchedPolicy::Lpt);
    let row_ptr = plan.row_ptr.clone();
    let nnz_total = *row_ptr.last().unwrap();
    let mut col_idx = vec![0 as Index; nnz_total];
    let mut data = vec![0.0 as Value; nnz_total];

    // Cooperative expiry: the first worker to see the deadline pass flips
    // the flag; every worker checks it per row (one relaxed load) and
    // abandons its remaining windows. The partial output is discarded.
    let expired = AtomicBool::new(false);
    let mut traffics = vec![Traffic::default(); threads];
    {
        let window_len = |w: &Window| row_ptr[w.row_end] - row_ptr[w.row_begin];
        let col_slices = split_disjoint(col_idx.as_mut_slice(), windows.iter().map(window_len));
        let data_slices = split_disjoint(data.as_mut_slice(), windows.iter().map(window_len));
        let mut work: Vec<Vec<(usize, &mut [Index], &mut [Value])>> =
            (0..threads).map(|_| Vec::new()).collect();
        for (wi, (cs, ds)) in col_slices.into_iter().zip(data_slices).enumerate() {
            work[assignment.window_to_block[wi]].push((wi, cs, ds));
        }
        let windows = &windows;
        let row_ptr = &row_ptr;
        let row_flops = &plan.row_flops;
        let expired = &expired;
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = work
            .into_iter()
            .zip(traffics.iter_mut())
            .enumerate()
            .filter(|(_, (chunk, _))| !chunk.is_empty())
            .map(|(worker, (chunk, traffic))| {
                Box::new(move || {
                    let mut t = Traffic::default();
                    // One accumulator per worker, reused across its rows:
                    // dense scratch materializes only if a row crosses
                    // the threshold, so hypersparse inputs keep worker
                    // memory at O(live row nnz), not O(b.cols).
                    let mut racc = RowAccumulator::with_semiring(b.cols, policy, semiring);
                    let mut rows_done = 0u32;
                    'windows: for (wi, cols_out, data_out) in chunk {
                        let w = &windows[wi];
                        let base = row_ptr[w.row_begin];
                        for i in w.row_begin..w.row_end {
                            if expired.load(Ordering::Relaxed) {
                                break 'windows;
                            }
                            if let Some(dl) = deadline {
                                rows_done += 1;
                                if rows_done % DEADLINE_POLL_ROWS == 0 && Instant::now() >= dl {
                                    expired.store(true, Ordering::Relaxed);
                                    break 'windows;
                                }
                            }
                            // Fault site `numeric_row`: on the pool
                            // worker, inside the row loop — a panic here
                            // exercises task quarantine.
                            faults::hit(FaultSite::NumericRow, Some(worker));
                            let lo = row_ptr[i] - base;
                            let hi = row_ptr[i + 1] - base;
                            racc.numeric_row(
                                a,
                                b,
                                i,
                                row_flops[i],
                                &mut cols_out[lo..hi],
                                &mut data_out[lo..hi],
                                &mut t,
                            );
                        }
                    }
                    // Fault site `drain`: end of a worker's chunk, just
                    // before its accumulator stats drain.
                    faults::hit(FaultSite::Drain, Some(worker));
                    t.accum = racc.finish();
                    *traffic = t;
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        run_scoped_try(tasks, exec).map_err(ParError::Panicked)?;
    }

    // Final checkpoint: catches both cooperative expiry above and a
    // deadline crossed late in a worker (e.g. an injected delay on the
    // last row, under DEADLINE_POLL_ROWS rows from the previous poll).
    if expired.load(Ordering::Relaxed)
        || deadline.is_some_and(|dl| Instant::now() >= dl)
    {
        return Err(ParError::DeadlineExceeded);
    }

    let mut t = Traffic::default();
    for p in &traffics {
        t.merge(p);
    }

    let c = Csr {
        rows: a.rows,
        cols: b.cols,
        row_ptr,
        col_idx,
        data,
    };
    debug_assert!(c.validate().is_ok());
    Ok((c, t))
}

/// Numeric phase of the propagation-blocking backend: same row windows
/// and LPT packing as [`numeric_with_plan`], but each worker owns one
/// *band-sized* accumulator and walks its rows band by band
/// ([`RowAccumulator::numeric_row_band`]), appending each band's sorted
/// drain at the row's output cursor. Bands ascend, so the concatenation
/// is the full row in ascending column order — bitwise equal to the
/// unblocked backend and the serial oracle.
#[allow(clippy::too_many_arguments)]
fn numeric_blocked_with_plan<S: Semiring>(
    a: &Csr,
    b: &Csr,
    threads: usize,
    plan: &SymbolicPlan,
    exec: Exec,
    policy: AccumPolicy,
    band_cols: usize,
    semiring: S,
) -> (Csr, Traffic) {
    let bands = BandPartition {
        band_cols,
        total_cols: b.cols,
    };
    let windows = partition_rows(&plan.row_flops, threads);
    let assignment = schedule_windows(&windows, threads, SchedPolicy::Lpt);
    let row_ptr = plan.row_ptr.clone();
    let nnz_total = *row_ptr.last().unwrap();
    let mut col_idx = vec![0 as Index; nnz_total];
    let mut data = vec![0.0 as Value; nnz_total];

    let mut traffics = vec![Traffic::default(); threads];
    {
        let window_len = |w: &Window| row_ptr[w.row_end] - row_ptr[w.row_begin];
        let col_slices = split_disjoint(col_idx.as_mut_slice(), windows.iter().map(window_len));
        let data_slices = split_disjoint(data.as_mut_slice(), windows.iter().map(window_len));
        let mut work: Vec<Vec<(usize, &mut [Index], &mut [Value])>> =
            (0..threads).map(|_| Vec::new()).collect();
        for (wi, (cs, ds)) in col_slices.into_iter().zip(data_slices).enumerate() {
            work[assignment.window_to_block[wi]].push((wi, cs, ds));
        }
        let windows = &windows;
        let row_ptr = &row_ptr;
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = work
            .into_iter()
            .zip(traffics.iter_mut())
            .filter(|(chunk, _)| !chunk.is_empty())
            .map(|(chunk, traffic)| {
                Box::new(move || {
                    let mut t = Traffic::default();
                    // One *band-sized* accumulator per worker: its dense
                    // lane is O(band_cols), never O(b.cols) — the blocked
                    // backend's memory contract.
                    let mut racc = RowAccumulator::with_semiring(band_cols, policy, semiring);
                    let mut segments = 0u64;
                    for (wi, cols_out, data_out) in chunk {
                        let w = &windows[wi];
                        let base = row_ptr[w.row_begin];
                        for i in w.row_begin..w.row_end {
                            let lo = row_ptr[i] - base;
                            let hi = row_ptr[i + 1] - base;
                            if hi == lo {
                                // Structurally empty output row: no band
                                // can emit anything (flops > 0 implies
                                // nnz > 0), so skip the whole band walk —
                                // on a hypersparse matrix this is nearly
                                // every row times every band.
                                continue;
                            }
                            let rowc = &mut cols_out[lo..hi];
                            let rowd = &mut data_out[lo..hi];
                            let mut cursor = 0usize;
                            for span in bands.ranges() {
                                let n = racc.numeric_row_band(a, b, i, span, &mut t, |j, v| {
                                    rowc[cursor] = j;
                                    rowd[cursor] = v;
                                    cursor += 1;
                                });
                                if n > 0 {
                                    segments += 1;
                                }
                            }
                            debug_assert_eq!(cursor, hi - lo, "row {i}: banded nnz mismatch");
                        }
                    }
                    let stats = racc.finish();
                    t.accum = stats;
                    t.band = BandStats {
                        band_cols: band_cols as u64,
                        bands: bands.count() as u64,
                        segments,
                        // The dense lane is allocated at the accumulator's
                        // width, so its column count is exactly band_cols
                        // whenever any segment went dense.
                        max_dense_lane_cols: if stats.dense_rows > 0 {
                            band_cols as u64
                        } else {
                            0
                        },
                    };
                    *traffic = t;
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        run_scoped(tasks, exec);
    }

    let mut t = Traffic::default();
    for p in &traffics {
        t.merge(p);
    }

    let c = Csr {
        rows: a.rows,
        cols: b.cols,
        row_ptr,
        col_idx,
        data,
    };
    debug_assert!(c.validate().is_ok());
    (c, t)
}

fn par_gustavson_blocked_exec<S: Semiring>(
    a: &Csr,
    b: &Csr,
    threads: usize,
    exec: Exec,
    spec: AccumSpec,
    bands: BandSpec,
    semiring: S,
) -> (Csr, Traffic, AccumPolicy) {
    assert_eq!(a.cols, b.rows, "dimension mismatch");
    let threads = threads.max(1);
    if a.rows == 0 {
        // No rows: nothing to band and no lane ever fires (mirrors
        // par_gustavson_exec).
        let (c, t) = gustavson(a, b);
        return (c, t, spec.resolve(bands.resolve(b.cols), &[]));
    }
    let plan = symbolic_plan_exec(a, b, threads, exec, spec);
    let band_cols = bands.resolve(b.cols);
    // Thresholds are relative to the accumulator width the numeric pass
    // actually uses — the band, not b.cols: a "heavy" band segment is one
    // that fills a meaningful fraction of the *band's* dense lane.
    let policy = spec.resolve(band_cols, &plan.row_flops);
    let (c, t) = numeric_blocked_with_plan(a, b, threads, &plan, exec, policy, band_cols, semiring);
    (c, t, policy)
}

fn par_gustavson_exec<S: Semiring>(
    a: &Csr,
    b: &Csr,
    threads: usize,
    exec: Exec,
    spec: AccumSpec,
    semiring: S,
) -> (Csr, Traffic, AccumPolicy) {
    assert_eq!(a.cols, b.rows, "dimension mismatch");
    let threads = threads.max(1);
    if a.rows == 0 {
        // No rows: nothing to partition and no lane ever fires, so the
        // serial oracle's (mode- and semiring-agnostic, all-zero) stats
        // and empty product are correct for every semiring.
        let (c, t) = gustavson(a, b);
        return (c, t, spec.resolve(b.cols, &[]));
    }
    // b.cols == 0 flows through the normal path: every row is an empty
    // product, and the requested lane is still the one reported in
    // `Traffic::accum` (the oracle fallback would mislabel forced-hash
    // rows as dense).
    let plan = symbolic_plan_exec(a, b, threads, exec, spec);
    let policy = spec.resolve(b.cols, &plan.row_flops);
    let (c, t) = numeric_with_plan(a, b, threads, &plan, exec, policy, semiring);
    (c, t, policy)
}

/// Parallel Gustavson SpGEMM over `threads` workers of the persistent
/// process-wide [`WorkerPool`], with the adaptive per-row accumulator
/// policy (hash light rows, dense heavy rows). Returns the canonical
/// (sorted, merged) CSR product — bitwise identical to [`gustavson`] —
/// and the summed traffic profile.
pub fn par_gustavson(a: &Csr, b: &Csr, threads: usize) -> (Csr, Traffic) {
    let (c, t, _) = par_gustavson_exec(a, b, threads, Exec::Pool, AccumSpec::default(), Arithmetic);
    (c, t)
}

/// [`par_gustavson`] with an explicit accumulator mode — forced dense
/// (the pre-adaptive behaviour) and forced hash exist for benchmarks and
/// the `serve --accum` flag; all three modes produce bitwise-identical
/// output.
pub fn par_gustavson_accum(a: &Csr, b: &Csr, threads: usize, accum: AccumMode) -> (Csr, Traffic) {
    let (c, t, _) =
        par_gustavson_exec(a, b, threads, Exec::Pool, AccumSpec::Fixed(accum), Arithmetic);
    (c, t)
}

/// [`par_gustavson`] with a full per-job [`AccumSpec`] (fixed mode,
/// explicit threshold, or the auto heuristic). Also returns the resolved
/// [`AccumPolicy`] the numeric pass actually ran — under
/// [`AccumSpec::Auto`] that is the per-matrix heuristic pick, which the
/// serving layer records on `Response::accum_policy`.
pub fn par_gustavson_spec(
    a: &Csr,
    b: &Csr,
    threads: usize,
    spec: AccumSpec,
) -> (Csr, Traffic, AccumPolicy) {
    par_gustavson_exec(a, b, threads, Exec::Pool, spec, Arithmetic)
}

/// [`par_gustavson_spec`] over an arbitrary [`Semiring`]: full parallel
/// pipeline (FLOP pass, symbolic pass, prefix sum, LPT windows, hybrid
/// accumulators) with the numeric fold swapped for the semiring's ⊕/⊗ —
/// the "one merge/accumulate engine serves many sparse workloads" move.
/// Output is bitwise identical to
/// [`spgemm_semiring`](super::spgemm_semiring) under the same semiring.
pub fn par_gustavson_semiring<S: Semiring>(
    a: &Csr,
    b: &Csr,
    threads: usize,
    spec: AccumSpec,
    semiring: S,
) -> (Csr, Traffic, AccumPolicy) {
    par_gustavson_exec(a, b, threads, Exec::Pool, spec, semiring)
}

/// [`par_gustavson_semiring`] dispatched from a runtime [`SemiringKind`]
/// (monomorphized per kind — no per-FLOP dispatch). The coordinator's
/// plan-less serving path.
pub fn par_gustavson_kind(
    a: &Csr,
    b: &Csr,
    threads: usize,
    spec: AccumSpec,
    kind: SemiringKind,
) -> (Csr, Traffic, AccumPolicy) {
    match kind {
        SemiringKind::Arithmetic => par_gustavson_semiring(a, b, threads, spec, Arithmetic),
        SemiringKind::Boolean => par_gustavson_semiring(a, b, threads, spec, Boolean),
        SemiringKind::MinPlus => par_gustavson_semiring(a, b, threads, spec, MinPlus),
        SemiringKind::MaxTimes => par_gustavson_semiring(a, b, threads, spec, MaxTimes),
    }
}

/// Propagation-blocking parallel Gustavson (Gu et al., arXiv:2002.11302):
/// the full pipeline of [`par_gustavson`], but the numeric pass cuts B's
/// columns into [`BandSpec`]-width bands and accumulates each row band by
/// band in a band-sized accumulator — the dense lane is O(band), never
/// O(b.cols), so wide hypersparse products keep the accumulator
/// scratchpad-resident. Per-band sorted drains concatenate in ascending
/// band order, so the output is bitwise identical to [`par_gustavson`]
/// and the serial [`gustavson`] oracle. Adaptive arithmetic entry point;
/// [`Traffic::band`] carries the band statistics.
pub fn par_gustavson_blocked(a: &Csr, b: &Csr, threads: usize, bands: BandSpec) -> (Csr, Traffic) {
    let (c, t, _) = par_gustavson_blocked_exec(
        a,
        b,
        threads,
        Exec::Pool,
        AccumSpec::default(),
        bands,
        Arithmetic,
    );
    (c, t)
}

/// [`par_gustavson_blocked`] with a per-job [`AccumSpec`] and an
/// arbitrary [`Semiring`]. Under [`AccumSpec::Auto`] (and the default
/// `cols/16`), thresholds resolve against the *band* width — the
/// accumulator the numeric pass actually holds.
pub fn par_gustavson_blocked_semiring<S: Semiring>(
    a: &Csr,
    b: &Csr,
    threads: usize,
    spec: AccumSpec,
    bands: BandSpec,
    semiring: S,
) -> (Csr, Traffic, AccumPolicy) {
    par_gustavson_blocked_exec(a, b, threads, Exec::Pool, spec, bands, semiring)
}

/// [`par_gustavson_blocked_semiring`] dispatched from a runtime
/// [`SemiringKind`] (monomorphized per kind) — what
/// [`Dataflow::ParGustavsonBlocked`](super::Dataflow::ParGustavsonBlocked)
/// and the coordinator's plan-less blocked path call.
pub fn par_gustavson_blocked_kind(
    a: &Csr,
    b: &Csr,
    threads: usize,
    spec: AccumSpec,
    bands: BandSpec,
    kind: SemiringKind,
) -> (Csr, Traffic, AccumPolicy) {
    match kind {
        SemiringKind::Arithmetic => {
            par_gustavson_blocked_semiring(a, b, threads, spec, bands, Arithmetic)
        }
        SemiringKind::Boolean => {
            par_gustavson_blocked_semiring(a, b, threads, spec, bands, Boolean)
        }
        SemiringKind::MinPlus => {
            par_gustavson_blocked_semiring(a, b, threads, spec, bands, MinPlus)
        }
        SemiringKind::MaxTimes => {
            par_gustavson_blocked_semiring(a, b, threads, spec, bands, MaxTimes)
        }
    }
}

/// Blocked numeric phase against a precomputed [`SymbolicPlan`] with a
/// fully resolved policy and band width — the blocked analogue of
/// [`par_gustavson_with_plan_policy`], and the `tune` band sweep's entry
/// point. Plans are band-independent, so the same cached plan serves
/// every swept width.
pub fn par_gustavson_blocked_with_plan_policy(
    a: &Csr,
    b: &Csr,
    threads: usize,
    plan: &SymbolicPlan,
    policy: AccumPolicy,
    band_cols: usize,
) -> (Csr, Traffic) {
    par_gustavson_blocked_with_plan_kind(
        a,
        b,
        threads,
        plan,
        policy,
        band_cols,
        SemiringKind::Arithmetic,
    )
}

/// [`par_gustavson_blocked_with_plan_policy`] dispatched from a runtime
/// [`SemiringKind`] — the coordinator's cached-plan blocked serving path.
pub fn par_gustavson_blocked_with_plan_kind(
    a: &Csr,
    b: &Csr,
    threads: usize,
    plan: &SymbolicPlan,
    policy: AccumPolicy,
    band_cols: usize,
    kind: SemiringKind,
) -> (Csr, Traffic) {
    assert_eq!(a.cols, b.rows, "dimension mismatch");
    assert_eq!(plan.row_ptr.len(), a.rows + 1, "plan is for a different A");
    let threads = threads.max(1);
    let band_cols = band_cols.clamp(1, b.cols.max(1));
    match kind {
        SemiringKind::Arithmetic => {
            numeric_blocked_with_plan(
                a,
                b,
                threads,
                plan,
                Exec::Pool,
                policy,
                band_cols,
                Arithmetic,
            )
        }
        SemiringKind::Boolean => {
            numeric_blocked_with_plan(a, b, threads, plan, Exec::Pool, policy, band_cols, Boolean)
        }
        SemiringKind::MinPlus => {
            numeric_blocked_with_plan(a, b, threads, plan, Exec::Pool, policy, band_cols, MinPlus)
        }
        SemiringKind::MaxTimes => {
            numeric_blocked_with_plan(a, b, threads, plan, Exec::Pool, policy, band_cols, MaxTimes)
        }
    }
}

/// [`par_gustavson`] with spawn-per-call execution (`std::thread::scope`)
/// instead of the persistent pool — the PR-1 behaviour, kept as the
/// benchmark baseline for the pooled-vs-spawn comparison in
/// `benches/hot_paths.rs`. Adaptive accumulator policy.
pub fn par_gustavson_spawning(a: &Csr, b: &Csr, threads: usize) -> (Csr, Traffic) {
    let (c, t, _) =
        par_gustavson_exec(a, b, threads, Exec::Spawn, AccumSpec::default(), Arithmetic);
    (c, t)
}

/// [`par_gustavson_semiring`] on the spawn-per-call backend — the
/// semiring parity suite exercises both executors so neither can quietly
/// regress to arithmetic-only.
pub fn par_gustavson_spawning_semiring<S: Semiring>(
    a: &Csr,
    b: &Csr,
    threads: usize,
    spec: AccumSpec,
    semiring: S,
) -> (Csr, Traffic, AccumPolicy) {
    par_gustavson_exec(a, b, threads, Exec::Spawn, spec, semiring)
}

/// [`par_gustavson_spawning_semiring`] dispatched from a runtime
/// [`SemiringKind`].
pub fn par_gustavson_spawning_kind(
    a: &Csr,
    b: &Csr,
    threads: usize,
    spec: AccumSpec,
    kind: SemiringKind,
) -> (Csr, Traffic, AccumPolicy) {
    match kind {
        SemiringKind::Arithmetic => {
            par_gustavson_spawning_semiring(a, b, threads, spec, Arithmetic)
        }
        SemiringKind::Boolean => par_gustavson_spawning_semiring(a, b, threads, spec, Boolean),
        SemiringKind::MinPlus => par_gustavson_spawning_semiring(a, b, threads, spec, MinPlus),
        SemiringKind::MaxTimes => par_gustavson_spawning_semiring(a, b, threads, spec, MaxTimes),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{erdos_renyi, rmat, RmatParams};
    use crate::spgemm::{flops_per_row, symbolic_row_nnz};

    #[test]
    fn partition_covers_rows_and_conserves_flops() {
        let flops = vec![5u64, 0, 1000, 3, 3, 3, 0, 90, 2, 1];
        let ws = partition_rows(&flops, 3);
        assert_eq!(ws.first().unwrap().row_begin, 0);
        assert_eq!(ws.last().unwrap().row_end, flops.len());
        for w in ws.windows(2) {
            assert_eq!(w[0].row_end, w[1].row_begin, "windows must tile rows");
        }
        assert!(ws.iter().all(|w| w.rows() >= 1));
        let total: u64 = ws.iter().map(|w| w.flops).sum();
        assert_eq!(total, flops.iter().sum::<u64>());
    }

    #[test]
    fn even_chunks_tile() {
        for (n, parts) in [(0usize, 3usize), (1, 4), (10, 3), (16, 4), (7, 16)] {
            let cs = even_chunks(n, parts);
            assert!(!cs.is_empty());
            assert_eq!(cs.first().unwrap().0, 0);
            assert_eq!(cs.last().unwrap().1, n);
            for pair in cs.windows(2) {
                assert_eq!(pair[0].1, pair[1].0);
            }
            let max = cs.iter().map(|&(s, e)| e - s).max().unwrap();
            let min = cs.iter().map(|&(s, e)| e - s).min().unwrap();
            assert!(max - min <= 1, "chunks must be near-equal");
        }
    }

    #[test]
    fn matches_serial_bitwise_across_thread_counts() {
        let a = rmat(&RmatParams::new(8, 3000, 5));
        let b = rmat(&RmatParams::new(8, 3000, 6));
        let (c1, t1) = gustavson(&a, &b);
        for threads in [1, 2, 3, 4, 7] {
            let (cp, tp) = par_gustavson(&a, &b, threads);
            // Same accumulation order per row -> bitwise equality, not
            // just approx_same.
            assert_eq!(c1.row_ptr, cp.row_ptr, "threads={threads}");
            assert_eq!(c1.col_idx, cp.col_idx, "threads={threads}");
            assert_eq!(c1.data, cp.data, "threads={threads}");
            assert_eq!(t1.flops, tp.flops, "threads={threads}");
            assert_eq!(t1.a_reads, tp.a_reads, "threads={threads}");
            assert_eq!(t1.b_reads, tp.b_reads, "threads={threads}");
            assert_eq!(t1.c_writes, tp.c_writes, "threads={threads}");
        }
    }

    #[test]
    fn spawning_variant_matches_pooled() {
        let a = rmat(&RmatParams::new(8, 2500, 11));
        let b = rmat(&RmatParams::new(8, 2500, 12));
        let (cp, tp) = par_gustavson(&a, &b, 4);
        let (cs, ts) = par_gustavson_spawning(&a, &b, 4);
        assert_eq!(cp.row_ptr, cs.row_ptr);
        assert_eq!(cp.col_idx, cs.col_idx);
        assert_eq!(cp.data, cs.data);
        assert_eq!(tp.flops, ts.flops);
    }

    #[test]
    fn plan_matches_serial_symbolic() {
        let a = rmat(&RmatParams::new(8, 3000, 21));
        let b = rmat(&RmatParams::new(8, 3000, 22));
        let plan = symbolic_plan(&a, &b, 4);
        assert_eq!(plan.row_flops, flops_per_row(&a, &b));
        assert_eq!(plan.row_nnz, symbolic_row_nnz(&a, &b));
        let mut acc = 0usize;
        for (i, &n) in plan.row_nnz.iter().enumerate() {
            assert_eq!(plan.row_ptr[i], acc);
            acc += n;
        }
        assert_eq!(plan.nnz(), acc);
        assert!(plan.resident_bytes() > 0);
        // Plans are thread-count independent (shareable across jobs).
        assert_eq!(plan, symbolic_plan(&a, &b, 7));
        // The parallel driver is a consumer of the pass pipeline: its
        // plan is field-for-field the serial reference composition's.
        assert_eq!(
            plan,
            crate::spgemm::plan::symbolic_plan_serial(&a, &b, AccumSpec::default())
        );
    }

    #[test]
    fn with_plan_matches_oracle_bitwise() {
        let a = rmat(&RmatParams::new(8, 3000, 31));
        let b = rmat(&RmatParams::new(8, 3000, 32));
        let (c1, t1) = gustavson(&a, &b);
        let plan = symbolic_plan(&a, &b, 4);
        for threads in [1, 3, 4] {
            let (cp, tp) = par_gustavson_with_plan(&a, &b, threads, &plan);
            assert_eq!(c1.row_ptr, cp.row_ptr, "threads={threads}");
            assert_eq!(c1.col_idx, cp.col_idx, "threads={threads}");
            assert_eq!(c1.data, cp.data, "threads={threads}");
            assert_eq!(t1.flops, tp.flops, "threads={threads}");
        }
    }

    /// Adaptive, forced-dense, forced-hash, and forced-merge backends are
    /// bitwise equal to the serial oracle on every generator — the
    /// tentpole acceptance bar.
    #[test]
    fn accum_modes_bitwise_equal_oracle() {
        use crate::gen::banded;
        let inputs: Vec<(&str, Csr, Csr)> = vec![
            (
                "rmat",
                rmat(&RmatParams::new(8, 2600, 41)),
                rmat(&RmatParams::new(8, 2600, 42)),
            ),
            (
                "erdos_renyi",
                erdos_renyi(128, 1200, 43),
                erdos_renyi(128, 1200, 44),
            ),
            ("banded", banded(96, 4, 45), banded(96, 3, 46)),
        ];
        for (name, a, b) in &inputs {
            let (c1, t1) = gustavson(a, b);
            for mode in [
                AccumMode::Adaptive,
                AccumMode::Dense,
                AccumMode::Hash,
                AccumMode::Merge,
            ] {
                for threads in [1, 3, 4] {
                    let (cp, tp) = par_gustavson_accum(a, b, threads, mode);
                    let label = format!("{name}/{}/t{threads}", mode.name());
                    assert_eq!(c1.row_ptr, cp.row_ptr, "{label}");
                    assert_eq!(c1.col_idx, cp.col_idx, "{label}");
                    assert_eq!(c1.data, cp.data, "{label}");
                    assert_eq!(t1.flops, tp.flops, "{label}");
                    assert_eq!(t1.c_writes, tp.c_writes, "{label}");
                    assert_eq!(
                        tp.accum.dense_rows + tp.accum.hash_rows + tp.accum.merge_rows,
                        a.rows as u64,
                        "{label}: numeric pass must route every row"
                    );
                }
            }
        }
    }

    /// Per-job thresholds: one cached plan serves every swept threshold
    /// and the auto heuristic; every point is bitwise equal to the oracle
    /// while the dense/hash row split moves monotonically with the
    /// threshold.
    #[test]
    fn threshold_sweep_shares_plan_and_stays_bitwise() {
        let a = rmat(&RmatParams::new(8, 2_800, 61));
        let b = rmat(&RmatParams::new(8, 2_800, 62));
        let (oracle, to) = gustavson(&a, &b);
        let plan = symbolic_plan(&a, &b, 4);
        let mut last_dense = u64::MAX;
        for threshold in [1u64, 4, 16, 64, 256, 1 << 20] {
            let policy = AccumPolicy::new(AccumMode::Adaptive, b.cols).with_threshold(threshold);
            let (c, t) = par_gustavson_with_plan_policy(&a, &b, 3, &plan, policy);
            assert_eq!(c.row_ptr, oracle.row_ptr, "t={threshold}");
            assert_eq!(c.col_idx, oracle.col_idx, "t={threshold}");
            assert_eq!(c.data, oracle.data, "t={threshold}");
            assert_eq!(t.flops, to.flops, "t={threshold}");
            assert_eq!(
                t.accum.dense_rows + t.accum.hash_rows + t.accum.merge_rows,
                a.rows as u64,
                "t={threshold}"
            );
            assert!(
                t.accum.dense_rows <= last_dense,
                "raising the threshold must not add dense rows \
                 (t={threshold}: {} > {last_dense})",
                t.accum.dense_rows
            );
            last_dense = t.accum.dense_rows;
        }
        // The auto spec resolves off the same plan's FLOPs distribution,
        // deterministically, and matches the oracle bitwise too.
        let (c, _, policy) = par_gustavson_spec(&a, &b, 3, AccumSpec::Auto);
        assert_eq!(c.data, oracle.data, "auto");
        assert_eq!(policy, AccumPolicy::auto_for(b.cols, &plan.row_flops));
        assert_eq!(policy.mode, AccumMode::Adaptive);
    }

    /// One semiring-invariant plan serves every semiring: the numeric
    /// pass under each kind stays bitwise equal to its serial oracle
    /// while reusing a single arithmetic-computed `SymbolicPlan`.
    #[test]
    fn one_plan_serves_every_semiring_bitwise() {
        use crate::spgemm::semiring::spgemm_semiring;
        let a = rmat(&RmatParams::new(8, 2_400, 71));
        let b = rmat(&RmatParams::new(8, 2_400, 72));
        let plan = symbolic_plan(&a, &b, 4);
        let policy = AccumPolicy::new(AccumMode::Adaptive, b.cols);
        for kind in SemiringKind::ALL {
            let oracle = spgemm_semiring(&a, &b, kind);
            for threads in [1, 3, 4] {
                let (c, t) = par_gustavson_with_plan_kind(&a, &b, threads, &plan, policy, kind);
                let label = format!("{}/t{threads}", kind.name());
                assert_eq!(c.row_ptr, oracle.row_ptr, "{label}");
                assert_eq!(c.col_idx, oracle.col_idx, "{label}");
                assert_eq!(c.data, oracle.data, "{label}");
                assert_eq!(
                    t.accum.dense_rows + t.accum.hash_rows + t.accum.merge_rows,
                    a.rows as u64,
                    "{label}: numeric pass must route every row"
                );
            }
        }
    }

    /// The blocked backend is bitwise equal to the unblocked one (and so
    /// to the serial oracle) for every band width, with band stats
    /// surfacing the bounded dense lane. Exhaustive semiring × mode ×
    /// generator coverage lives in `tests/blocked_parity.rs`; this is the
    /// fast in-module gate.
    #[test]
    fn blocked_matches_oracle_across_band_widths() {
        let a = rmat(&RmatParams::new(8, 2_600, 201));
        let b = rmat(&RmatParams::new(8, 2_600, 202));
        let (oracle, to) = gustavson(&a, &b);
        for bands in [
            BandSpec::Cols(1),
            BandSpec::Cols(64),
            BandSpec::Cols(b.cols),
            BandSpec::Auto,
        ] {
            for threads in [1, 3, 4] {
                let (c, t) = par_gustavson_blocked(&a, &b, threads, bands);
                let label = format!("bands={}/t{threads}", bands.describe());
                assert_eq!(c.row_ptr, oracle.row_ptr, "{label}");
                assert_eq!(c.col_idx, oracle.col_idx, "{label}");
                assert_eq!(c.data, oracle.data, "{label}");
                // Banding re-walks A per band but performs the same
                // useful work: FLOPs and output writes are conserved.
                assert_eq!(t.flops, to.flops, "{label}");
                assert_eq!(t.c_writes, to.c_writes, "{label}");
                let width = bands.resolve(b.cols) as u64;
                assert_eq!(t.band.band_cols, width, "{label}");
                assert_eq!(
                    t.band.bands,
                    (b.cols as u64).div_ceil(width),
                    "{label}"
                );
                assert!(
                    t.band.max_dense_lane_cols <= width,
                    "{label}: dense lane {} wider than the band",
                    t.band.max_dense_lane_cols
                );
                // Every nonempty output row accumulates in ≥ 1 band.
                let nonempty = oracle.row_ptr.windows(2).filter(|w| w[1] > w[0]).count() as u64;
                assert!(t.band.segments >= nonempty, "{label}");
            }
        }
        // The unblocked backend reports zeroed band stats.
        let (_, t) = par_gustavson(&a, &b, 4);
        assert_eq!(t.band, BandStats::default());
    }

    /// The memory story: on a hypersparse wide input the adaptive policy
    /// keeps per-worker accumulator bytes at O(live row nnz), while the
    /// forced-dense baseline pins O(b.cols) per worker.
    #[test]
    fn adaptive_worker_memory_is_o_live_nnz_on_hypersparse() {
        // Erdős–Rényi at this sparsity has no hub rows: every row's FLOPs
        // bound sits orders of magnitude under the cols/16 threshold, so
        // the adaptive policy hashes everything.
        let a = erdos_renyi(1 << 15, 4_000, 51);
        let b = erdos_renyi(1 << 15, 4_000, 52);
        let cols = b.cols as u64;
        let (ca, ta) = par_gustavson_accum(&a, &b, 4, AccumMode::Adaptive);
        let (cd, td) = par_gustavson_accum(&a, &b, 4, AccumMode::Dense);
        assert_eq!(ca.data, cd.data, "lanes must agree bitwise");
        let dense_floor = cols * 9; // acc (8 B) + present (1 B) per column
        assert!(
            td.accum.peak_bytes >= dense_floor,
            "dense lane must pin O(cols): {} < {dense_floor}",
            td.accum.peak_bytes
        );
        assert!(
            ta.accum.peak_bytes * 8 < dense_floor,
            "adaptive peak {} B should be far under the dense floor {dense_floor} B",
            ta.accum.peak_bytes
        );
        assert_eq!(ta.accum.dense_rows, 0, "no hypersparse row crosses cols/16");
    }

    #[test]
    fn degenerate_shapes() {
        let z = Csr::zero(6, 6);
        let (c, t) = par_gustavson(&z, &z, 4);
        assert_eq!(c.nnz(), 0);
        assert_eq!(t.flops, 0);
        let i = Csr::identity(17);
        let a = erdos_renyi(17, 60, 3);
        let (c, _) = par_gustavson(&a, &i, 3);
        assert!(c.approx_same(&a));
        // more threads than rows
        let tiny = erdos_renyi(2, 3, 9);
        let (c, _) = par_gustavson(&tiny, &tiny, 16);
        let (oracle, _) = gustavson(&tiny, &tiny);
        assert!(c.approx_same(&oracle));
    }

    /// The pool is persistent: repeated scopes reuse the same workers;
    /// growth happens only on demand (a larger task set), never per
    /// scope. (Uses a private pool — the global one is shared with
    /// concurrently running tests.)
    #[test]
    fn pool_workers_are_reused_across_scopes() {
        let pool = WorkerPool::new(3);
        assert_eq!(pool.workers(), 3);
        let run_scope = |tasks: usize| {
            let counter = std::sync::atomic::AtomicUsize::new(0);
            let boxed: Vec<Box<dyn FnOnce() + Send + '_>> = (0..tasks)
                .map(|_| {
                    Box::new(|| {
                        counter.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.scope(boxed);
            assert_eq!(counter.load(std::sync::atomic::Ordering::SeqCst), tasks);
        };
        run_scope(2);
        assert_eq!(pool.workers(), 3, "small scopes never grow the pool");
        run_scope(5);
        assert_eq!(pool.workers(), 5, "pool grows on demand");
        for _ in 0..4 {
            run_scope(5);
        }
        assert_eq!(pool.workers(), 5, "repeat scopes reuse workers");
        // The global pool is one process-wide instance.
        assert!(std::ptr::eq(WorkerPool::global(), WorkerPool::global()));
        assert!(WorkerPool::global().workers() >= 1);
    }

    /// A panicking task does not kill its worker or wedge the pool: the
    /// panic propagates to the scope caller and the pool stays usable.
    #[test]
    fn pool_survives_task_panic() {
        let pool = WorkerPool::new(2);
        let tasks: Vec<Box<dyn FnOnce() + Send>> = vec![
            Box::new(|| {}),
            Box::new(|| panic!("deliberate test panic")),
            Box::new(|| {}),
        ];
        let caught = catch_unwind(AssertUnwindSafe(|| pool.scope(tasks)));
        assert!(caught.is_err(), "scope must re-raise the task panic");
        // Still serviceable afterwards.
        let counter = std::sync::atomic::AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.scope(tasks);
        assert_eq!(counter.load(std::sync::atomic::Ordering::SeqCst), 4);
    }

    /// `try_scope` quarantines every task panic as a typed, attributed
    /// error — nothing unwinds into the caller, completed siblings still
    /// ran, and the pool stays serviceable without a catch_unwind wrapper.
    #[test]
    fn try_scope_quarantines_panics_per_task() {
        let pool = WorkerPool::new(2);
        let ran = std::sync::atomic::AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
            Box::new(|| {
                ran.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            }),
            Box::new(|| panic!("boom static")),
            Box::new(|| panic!("boom {}", "formatted")),
            Box::new(|| {
                ran.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            }),
        ];
        let errs = pool.try_scope(tasks).unwrap_err();
        assert_eq!(errs.len(), 2, "exactly the two panicking tasks");
        assert_eq!(errs[0], TaskPanic { task: 1, message: "boom static".into() });
        assert_eq!(errs[1], TaskPanic { task: 2, message: "boom formatted".into() });
        assert_eq!(ran.load(std::sync::atomic::Ordering::SeqCst), 2);
        // Still serviceable afterwards, and a clean scope returns Ok.
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
            .map(|_| {
                Box::new(|| {
                    ran.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.try_scope(tasks).expect("clean scope is Ok");
        assert_eq!(ran.load(std::sync::atomic::Ordering::SeqCst), 6);
    }

    /// The checked plan-backed entry: a deadline already in the past
    /// fails with `DeadlineExceeded` instead of serving a late result,
    /// while a generous deadline serves output bitwise-equal to the
    /// uncheck path.
    #[test]
    fn checked_path_honors_deadlines() {
        let a = rmat(&RmatParams::new(8, 2_000, 5));
        let b = rmat(&RmatParams::new(8, 2_000, 6));
        let plan = symbolic_plan(&a, &b, 2);
        let policy = AccumPolicy::new(AccumMode::Adaptive, b.cols);

        let past = Instant::now() - std::time::Duration::from_millis(1);
        match par_gustavson_with_plan_checked(
            &a, &b, 2, &plan, policy, SemiringKind::Arithmetic, Some(past),
        ) {
            Err(ParError::DeadlineExceeded) => {}
            other => panic!("expired deadline must fail typed, got {other:?}"),
        }

        let generous = Instant::now() + std::time::Duration::from_secs(600);
        let (c, t) = par_gustavson_with_plan_checked(
            &a, &b, 2, &plan, policy, SemiringKind::Arithmetic, Some(generous),
        )
        .expect("generous deadline serves normally");
        let (c_ref, t_ref) = par_gustavson_with_plan(&a, &b, 2, &plan);
        assert_eq!(c.row_ptr, c_ref.row_ptr);
        assert_eq!(c.col_idx, c_ref.col_idx);
        assert_eq!(c.data, c_ref.data, "checked path must stay bitwise-equal");
        assert_eq!(t.flops, t_ref.flops);
    }

    /// The acceptance bar: on an R-MAT scale-13 input, 4 threads must (a)
    /// match the serial oracle exactly and (b) beat it in wall-clock.
    /// The timing half is skipped on machines without real parallelism.
    #[test]
    fn par4_beats_serial_on_rmat_scale13() {
        let a = rmat(&RmatParams::new(13, 260_000, 1));
        let b = rmat(&RmatParams::new(13, 260_000, 2));
        let (c1, _) = gustavson(&a, &b);
        let (c4, _) = par_gustavson(&a, &b, 4);
        assert_eq!(c1.row_ptr, c4.row_ptr);
        assert_eq!(c1.col_idx, c4.col_idx);
        assert_eq!(c1.data, c4.data, "par output must match the oracle exactly");

        // The timing half is opt-in (SMASH_TIMING_TESTS=1): wall-clock
        // inversion on a loaded shared runner — or fewer than 4 real
        // cores — is environment noise, not a code defect, so default CI
        // never gates on it. The bitwise-equality half above always runs.
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        if std::env::var("SMASH_TIMING_TESTS").as_deref() != Ok("1") {
            eprintln!("skipping wall-clock assertion: set SMASH_TIMING_TESTS=1 to enable");
            return;
        }
        if cores < 4 {
            eprintln!("skipping wall-clock assertion: {cores} core(s) available");
            return;
        }
        let best_of = |f: &dyn Fn() -> (Csr, Traffic)| {
            (0..3)
                .map(|_| {
                    let t0 = std::time::Instant::now();
                    std::hint::black_box(f());
                    t0.elapsed()
                })
                .min()
                .unwrap()
        };
        // Sibling tests run concurrently in the same binary and can steal
        // cores mid-sample; retry once so a transient squeeze on the par
        // samples does not fail the build.
        for attempt in 0..2 {
            let serial = best_of(&|| gustavson(&a, &b));
            let par = best_of(&|| par_gustavson(&a, &b, 4));
            if par < serial {
                return;
            }
            if attempt == 1 {
                panic!("par_gustavson(4) took {par:?}, serial gustavson {serial:?}");
            }
            eprintln!("timing inverted ({par:?} vs {serial:?}); retrying once");
        }
    }
}
