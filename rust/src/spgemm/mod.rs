//! Reference SpGEMM dataflows (thesis §1.5, Table 1.2) with memory-traffic
//! accounting, plus the Gustavson oracle used to verify every SMASH kernel.
//!
//! These run natively (no simulator) and serve three purposes:
//! 1. correctness oracle ([`gustavson()`]);
//! 2. the Table 1.2 dataflow comparison (input/output reuse, intermediate
//!    size) regenerated from measured counters;
//! 3. fast CPU baselines for the benchmark harness.

mod accumulator;
pub mod graph;
mod gustavson;
mod inner;
mod intensity;
mod outer;
mod par;
pub mod plan;
mod rowwise;
pub mod semiring;

pub use accumulator::{
    AccumMode, AccumPolicy, AccumSpec, AccumStats, RowAccumulator, AUTO_DIVISOR_MAX,
    AUTO_DIVISOR_MIN, HASH_THRESHOLD_DIVISOR, MERGE_DEPTH_BUCKETS, MERGE_MAX_K_DEFAULT,
    MERGE_MIN_AVG_RUN,
};
pub use gustavson::{flops_per_row, gustavson, symbolic_row_nnz, total_flops};
pub use inner::inner_product;
pub use intensity::{arithmetic_intensity, compression_factor, IntensityReport};
pub use outer::outer_product;
pub use par::{
    panic_message, par_gustavson, par_gustavson_accum, par_gustavson_blocked,
    par_gustavson_blocked_kind, par_gustavson_blocked_semiring,
    par_gustavson_blocked_with_plan_kind, par_gustavson_blocked_with_plan_policy,
    par_gustavson_kind, par_gustavson_semiring, par_gustavson_spawning,
    par_gustavson_spawning_kind, par_gustavson_spawning_semiring, par_gustavson_spec,
    par_gustavson_with_plan, par_gustavson_with_plan_accum, par_gustavson_with_plan_checked,
    par_gustavson_with_plan_kind, par_gustavson_with_plan_policy,
    par_gustavson_with_plan_semiring, symbolic_plan, ParError, TaskPanic, WorkerPool,
};
pub use plan::{symbolic_plan_serial, BandPartition, BandSpec, SymbolicPlan};
pub use rowwise::{rowwise_hash, rowwise_heap};
pub use semiring::{
    ewise_add, spgemm_semiring, Arithmetic, Boolean, MaxTimes, MinPlus, Semiring, SemiringKind,
};

pub use crate::faults::FaultStats;

use crate::formats::Csr;

/// Memory-traffic counters for one SpGEMM execution (element granularity;
/// multiply by element size for bytes). Drives the Table 1.2 reproduction.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Traffic {
    /// Elements read from matrix A (counting redundant re-reads).
    pub a_reads: u64,
    /// Elements read from matrix B (counting redundant re-reads).
    pub b_reads: u64,
    /// Elements written to the final output C.
    pub c_writes: u64,
    /// Partial-product elements written to intermediate storage.
    pub intermediate_writes: u64,
    /// Partial-product elements read back for merging.
    pub intermediate_reads: u64,
    /// Peak live intermediate elements (the "Intermediate Size" column).
    pub intermediate_peak: u64,
    /// Fused multiply-adds performed.
    pub flops: u64,
    /// Accumulator-policy statistics of the numeric pass (dense vs hash
    /// vs merge rows, probe counts, merge-depth histogram, peak
    /// per-worker accumulator bytes) — zero for dataflows that do not
    /// use the [`RowAccumulator`].
    pub accum: AccumStats,
    /// Column-band statistics of the propagation-blocking backend
    /// ([`par_gustavson_blocked`]) — zero for every unblocked dataflow.
    pub band: BandStats,
    /// Fault-plane observability for this execution: injection-site
    /// evaluations observed / faults fired while the job ran, plus the
    /// failed/shed/expired job counters the coordinator folds in at the
    /// aggregate level. All-zero whenever the fault plane is disarmed
    /// (the production case).
    pub faults: FaultStats,
}

/// Column-band counters of one blocked multiply, carried on
/// [`Traffic::band`]. The load-bearing invariant is
/// `max_dense_lane_cols <= band_cols`: banding bounds the dense
/// accumulator lane by construction, and these stats surface that bound
/// so tests and the serving layer can assert it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BandStats {
    /// Configured band width in columns (0 when the multiply was
    /// unblocked).
    pub band_cols: u64,
    /// Column bands the partition produced (`⌈b.cols / band_cols⌉`).
    pub bands: u64,
    /// (row, band) segments that accumulated at least one product —
    /// empty segments are skipped without touching a lane.
    pub segments: u64,
    /// Widest dense accumulator lane any worker materialized; stays 0 if
    /// every segment hashed, and never exceeds `band_cols`.
    pub max_dense_lane_cols: u64,
}

impl BandStats {
    /// Fold another worker's band stats in: segment counts add, widths
    /// and band counts (identical across workers of one multiply) take
    /// the max.
    pub fn merge(&mut self, o: &BandStats) {
        self.band_cols = self.band_cols.max(o.band_cols);
        self.bands = self.bands.max(o.bands);
        self.segments += o.segments;
        self.max_dense_lane_cols = self.max_dense_lane_cols.max(o.max_dense_lane_cols);
    }
}

impl Traffic {
    /// Fold another worker's traffic share in: counters add, peaks take
    /// the max.
    pub fn merge(&mut self, o: &Traffic) {
        self.a_reads += o.a_reads;
        self.b_reads += o.b_reads;
        self.c_writes += o.c_writes;
        self.intermediate_writes += o.intermediate_writes;
        self.intermediate_reads += o.intermediate_reads;
        self.intermediate_peak = self.intermediate_peak.max(o.intermediate_peak);
        self.flops += o.flops;
        self.accum.merge(&o.accum);
        self.band.merge(&o.band);
        self.faults.merge(&o.faults);
    }

    /// Input reuse factor: useful input elements / total input reads.
    /// 1.0 = each input element read exactly once (perfect reuse).
    pub fn input_reuse(&self, a_nnz: u64, b_nnz: u64) -> f64 {
        let reads = (self.a_reads + self.b_reads) as f64;
        if reads == 0.0 {
            return 1.0;
        }
        (a_nnz + b_nnz) as f64 / reads
    }

    /// Output reuse factor: final C elements / total output-side writes
    /// (C + intermediates). 1.0 = every write lands in final C directly.
    pub fn output_reuse(&self) -> f64 {
        let writes = (self.c_writes + self.intermediate_writes) as f64;
        if writes == 0.0 {
            return 1.0;
        }
        self.c_writes as f64 / writes
    }
}

/// The four dataflows of Figure 1.2, plus the multicore serving backend
/// ([`par_gustavson`] — row-partitioned Gustavson over OS threads).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dataflow {
    Inner,
    Outer,
    RowWiseHeap,
    RowWiseHash,
    /// Row-partitioned parallel Gustavson with this many threads, executed
    /// on the persistent [`WorkerPool`], with a per-job accumulator spec
    /// (fixed mode, explicit threshold, or the per-matrix auto heuristic;
    /// `AccumSpec::default()` — adaptive at `cols/16` — is the serving
    /// default) and a per-job [`SemiringKind`] (arithmetic by default;
    /// boolean/min-plus/max-times put graph workloads on the same fast
    /// path). Jobs that differ only in `accum` or `semiring` still share
    /// one cached symbolic plan — the plan is value-free.
    ParGustavson { threads: usize, accum: AccumSpec, semiring: SemiringKind },
    /// [`ParGustavson`](Dataflow::ParGustavson) with propagation
    /// blocking ([`par_gustavson_blocked`]): B's columns are cut into
    /// [`BandSpec`]-width bands and each worker accumulates one band at a
    /// time in a band-sized accumulator, so the dense lane is O(band)
    /// instead of O(b.cols). Output is bitwise identical to the
    /// unblocked backend. `bands` is a *plan-cache key* parameter in the
    /// serving layer (blocked and unblocked jobs on one registered pair
    /// use distinct slots), though the cached plan contents are
    /// band-independent.
    ParGustavsonBlocked {
        threads: usize,
        accum: AccumSpec,
        semiring: SemiringKind,
        bands: BandSpec,
    },
    /// [`ParGustavson`](Dataflow::ParGustavson) with spawn-per-call
    /// execution instead of the pool — the benchmark baseline for the
    /// pooled-vs-spawn serving comparison. Always adaptive.
    ParGustavsonSpawn { threads: usize },
}

impl Dataflow {
    /// The serial reference dataflows of Figure 1.2 (the Table 1.2 set —
    /// excludes the parallel backend, which shares row-wise traffic
    /// characteristics by construction).
    pub const ALL: [Dataflow; 4] = [
        Dataflow::Inner,
        Dataflow::Outer,
        Dataflow::RowWiseHeap,
        Dataflow::RowWiseHash,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Dataflow::Inner => "Inner Product",
            Dataflow::Outer => "Outer Product",
            Dataflow::RowWiseHeap => "Row-wise (heap)",
            Dataflow::RowWiseHash => "Row-wise (hash)",
            Dataflow::ParGustavson { .. } => "Parallel Gustavson",
            Dataflow::ParGustavsonBlocked { .. } => "Parallel Gustavson (blocked)",
            Dataflow::ParGustavsonSpawn { .. } => "Parallel Gustavson (spawn)",
        }
    }

    /// Run this dataflow, returning (C, traffic).
    pub fn multiply(&self, a: &Csr, b: &Csr) -> (Csr, Traffic) {
        match self {
            Dataflow::Inner => inner_product(a, b),
            Dataflow::Outer => outer_product(a, b),
            Dataflow::RowWiseHeap => rowwise_heap(a, b),
            Dataflow::RowWiseHash => rowwise_hash(a, b),
            Dataflow::ParGustavson { threads, accum, semiring } => {
                let (c, t, _) = par_gustavson_kind(a, b, *threads, *accum, *semiring);
                (c, t)
            }
            Dataflow::ParGustavsonBlocked {
                threads,
                accum,
                semiring,
                bands,
            } => {
                let (c, t, _) =
                    par_gustavson_blocked_kind(a, b, *threads, *accum, *bands, *semiring);
                (c, t)
            }
            Dataflow::ParGustavsonSpawn { threads } => par_gustavson_spawning(a, b, *threads),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{erdos_renyi, rmat, RmatParams};

    /// All four dataflows must agree with the Gustavson oracle.
    #[test]
    fn dataflows_agree_with_oracle() {
        let a = rmat(&RmatParams::new(6, 300, 1));
        let b = rmat(&RmatParams::new(6, 300, 2));
        let (oracle, _) = gustavson(&a, &b);
        for df in Dataflow::ALL {
            let (c, t) = df.multiply(&a, &b);
            assert!(
                c.approx_same(&oracle),
                "{} disagrees with oracle",
                df.name()
            );
            assert!(t.flops > 0);
            assert_eq!(t.c_writes, oracle.nnz() as u64, "{}", df.name());
        }
    }

    /// The parallel backend plugs into the same `Dataflow::multiply`
    /// surface with its traffic counters intact.
    #[test]
    fn par_dataflow_matches_oracle_with_traffic() {
        let a = rmat(&RmatParams::new(7, 800, 3));
        let b = rmat(&RmatParams::new(7, 800, 4));
        let (oracle, serial_t) = gustavson(&a, &b);
        let df = Dataflow::ParGustavson {
            threads: 4,
            accum: AccumSpec::default(),
            semiring: SemiringKind::Arithmetic,
        };
        let (c, t) = df.multiply(&a, &b);
        assert!(c.approx_same(&oracle), "{} disagrees with oracle", df.name());
        assert_eq!(t.flops, serial_t.flops);
        assert_eq!(t.c_writes, oracle.nnz() as u64);
        assert_eq!(t.a_reads, serial_t.a_reads);
        assert_eq!(t.b_reads, serial_t.b_reads);
        // the adaptive policy routed every row through exactly one lane
        assert_eq!(
            t.accum.dense_rows + t.accum.hash_rows + t.accum.merge_rows,
            a.rows as u64
        );
    }

    /// Table 1.2 qualitative shape: outer product reads inputs once but has
    /// large intermediates; inner product re-reads inputs heavily; row-wise
    /// has small intermediates.
    #[test]
    fn table_1_2_shape() {
        let a = erdos_renyi(128, 1500, 3);
        let b = erdos_renyi(128, 1500, 4);
        let (_, ti) = inner_product(&a, &b);
        let (_, to) = outer_product(&a, &b);
        let (_, trh) = rowwise_hash(&a, &b);
        let a_nnz = a.nnz() as u64;
        let b_nnz = b.nnz() as u64;

        // outer: near-perfect input reuse (≈0.67 here: the CSC conversion
        // pass re-reads A once); inner: poor input reuse
        assert!(to.input_reuse(a_nnz, b_nnz) > 0.55);
        assert!(ti.input_reuse(a_nnz, b_nnz) < 0.2);
        // outer: poor output reuse (large intermediate); row-wise: good
        assert!(to.output_reuse() < 0.5);
        assert!(trh.output_reuse() > 0.9);
        // intermediate sizes
        assert!(to.intermediate_peak > 4 * trh.intermediate_peak.max(1));
    }
}
