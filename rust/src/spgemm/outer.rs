//! Outer-product dataflow (Eq. 1.2): `C = Σ_n col_n(A) × row_n(B)`.
//!
//! Reads each input element exactly once (perfect input reuse) but
//! materializes every partial product before a merge phase — the
//! OuterSPACE / SpArch two-phase structure (§3.3). The traffic counters
//! expose the large intermediate size that motivates SMASH.

use super::Traffic;
use crate::formats::{Csc, Csr, Index, Value};

/// Multiply via outer products with an explicit multiply phase (partial
/// product triplet lists) then a merge phase (sort + accumulate).
pub fn outer_product(a: &Csr, b: &Csr) -> (Csr, Traffic) {
    assert_eq!(a.cols, b.rows, "dimension mismatch");
    let mut t = Traffic::default();

    // A must be column-accessible (opposite format of row-wise — §1.5).
    let ac = Csc::from_csr(a);
    t.a_reads += a.nnz() as u64;

    // ---- multiply phase: emit all partial products ----
    // key = (row << 32 | col), kept as flat vec: this IS the intermediate.
    let mut partials: Vec<(u64, Value)> = Vec::new();
    for k in 0..ac.cols {
        let (arows, avals) = ac.col(k);
        let (bcols, bvals) = b.row(k);
        t.a_reads += arows.len() as u64;
        t.b_reads += bcols.len() as u64;
        for (&ar, &av) in arows.iter().zip(avals) {
            for (&bc_, &bv) in bcols.iter().zip(bvals) {
                partials.push((((ar as u64) << 32) | bc_ as u64, av * bv));
                t.flops += 1;
                t.intermediate_writes += 1;
            }
        }
    }
    t.intermediate_peak = partials.len() as u64;

    // ---- merge phase: sort partials and accumulate runs ----
    partials.sort_unstable_by_key(|(k, _)| *k);
    t.intermediate_reads += partials.len() as u64;

    let mut row_ptr = vec![0usize; a.rows + 1];
    let mut col_idx: Vec<Index> = Vec::new();
    let mut data: Vec<Value> = Vec::new();
    let mut i = 0;
    while i < partials.len() {
        let key = partials[i].0;
        let mut acc = 0.0;
        while i < partials.len() && partials[i].0 == key {
            acc += partials[i].1;
            i += 1;
        }
        let r = (key >> 32) as usize;
        row_ptr[r + 1] += 1;
        col_idx.push((key & 0xFFFF_FFFF) as Index);
        data.push(acc);
        t.c_writes += 1;
    }
    for r in 0..a.rows {
        row_ptr[r + 1] += row_ptr[r];
    }

    let c = Csr {
        rows: a.rows,
        cols: b.cols,
        row_ptr,
        col_idx,
        data,
    };
    debug_assert!(c.validate().is_ok());
    (c, t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{erdos_renyi, rmat, RmatParams};
    use crate::spgemm::gustavson;

    #[test]
    fn matches_oracle() {
        for seed in 0..4 {
            let a = rmat(&RmatParams::new(6, 250, seed));
            let b = rmat(&RmatParams::new(6, 250, seed + 50));
            let (c, _) = outer_product(&a, &b);
            let (o, _) = gustavson(&a, &b);
            assert!(c.approx_same(&o), "seed {seed}");
        }
    }

    #[test]
    fn perfect_input_reuse_large_intermediate() {
        let a = erdos_renyi(64, 600, 7);
        let b = erdos_renyi(64, 600, 8);
        let (c, t) = outer_product(&a, &b);
        // every input element read once in multiply phase (+1 conversion pass)
        assert!(t.input_reuse(a.nnz() as u64, b.nnz() as u64) > 0.45);
        // intermediate equals flop count (each FMA materialized)
        assert_eq!(t.intermediate_writes, t.flops);
        assert!(t.intermediate_peak as usize >= c.nnz());
    }

    #[test]
    fn empty_input() {
        let z = Csr::zero(8, 8);
        let (c, t) = outer_product(&z, &z);
        assert_eq!(c.nnz(), 0);
        assert_eq!(t.intermediate_peak, 0);
    }
}
