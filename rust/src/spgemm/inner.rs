//! Inner-product dataflow (Eq. 1.1): `C[i][j] = Σ_k A[i][k] · B[k][j]`.
//!
//! Requires B column-access → we pre-build CSC (counted as one full read of
//! B for the format conversion, matching the thesis' point that inner/outer
//! need opposite storage formats). Exhibits poor input reuse: row i of A is
//! re-walked for every column j with any structural overlap.

use super::Traffic;
use crate::formats::{Csc, Csr};

/// Multiply via sorted-merge dot products of A-rows with B-columns.
pub fn inner_product(a: &Csr, b: &Csr) -> (Csr, Traffic) {
    assert_eq!(a.cols, b.rows, "dimension mismatch");
    let mut t = Traffic::default();

    // Format conversion: one full pass over B.
    let bc = Csc::from_csr(b);
    t.b_reads += b.nnz() as u64;

    let mut triplets = Vec::new();
    for i in 0..a.rows {
        let (acols, avals) = a.row(i);
        if acols.is_empty() {
            continue;
        }
        for j in 0..bc.cols {
            let (brows, bvals) = bc.col(j);
            if brows.is_empty() {
                continue;
            }
            // Sorted-merge dot product; count every element touched.
            let (mut x, mut y) = (0usize, 0usize);
            let mut acc = 0.0;
            let mut any = false;
            while x < acols.len() && y < brows.len() {
                t.a_reads += 1;
                t.b_reads += 1;
                match acols[x].cmp(&brows[y]) {
                    std::cmp::Ordering::Less => x += 1,
                    std::cmp::Ordering::Greater => y += 1,
                    std::cmp::Ordering::Equal => {
                        acc += avals[x] * bvals[y];
                        t.flops += 1;
                        any = true;
                        x += 1;
                        y += 1;
                    }
                }
            }
            if any {
                triplets.push((i, j, acc));
                t.c_writes += 1;
            }
        }
    }
    // Inner product has no intermediate partial-product storage.
    t.intermediate_peak = 0;
    (Csr::from_triplets(a.rows, b.cols, triplets), t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::erdos_renyi;
    use crate::spgemm::gustavson;

    #[test]
    fn matches_oracle() {
        let a = erdos_renyi(30, 120, 1);
        let b = erdos_renyi(30, 120, 2);
        let (c, _) = inner_product(&a, &b);
        let (o, _) = gustavson(&a, &b);
        assert!(c.approx_same(&o));
    }

    #[test]
    fn redundant_reads_dominate() {
        let a = erdos_renyi(64, 512, 3);
        let b = erdos_renyi(64, 512, 4);
        let (_, t) = inner_product(&a, &b);
        // Poor input reuse: many more reads than nnz
        assert!(t.a_reads > 4 * a.nnz() as u64);
        assert_eq!(t.intermediate_writes, 0);
    }

    /// Structural overlap that cancels numerically must still emit an
    /// explicit entry (matches Gustavson's behaviour).
    #[test]
    fn keeps_numeric_zeros() {
        let a = Csr::from_triplets(1, 2, vec![(0, 0, 1.0), (0, 1, -1.0)]);
        let b = Csr::from_triplets(2, 1, vec![(0, 0, 1.0), (1, 0, 1.0)]);
        let (c, _) = inner_product(&a, &b);
        let (o, _) = gustavson(&a, &b);
        assert_eq!(c.nnz(), o.nnz());
    }
}
