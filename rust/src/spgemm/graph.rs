//! Graph algorithms built on semiring SpGEMM — the applications of
//! §1.3/§1.4 (path-finding, BFS, graph analysis) expressed as the linear
//! algebra the thesis targets.

use super::semiring::{ewise_add, spgemm_semiring, Boolean, MinPlus};
use crate::formats::{Csr, Value};

/// Multi-source BFS levels via repeated boolean SpMV (frontier × Aᵀ).
/// Returns `levels[v] = hops from the nearest source` (usize::MAX if
/// unreachable).
pub fn bfs_levels(adj: &Csr, sources: &[usize]) -> Vec<usize> {
    let n = adj.rows;
    let mut levels = vec![usize::MAX; n];
    let mut frontier: Vec<usize> = Vec::new();
    for &s in sources {
        assert!(s < n);
        levels[s] = 0;
        frontier.push(s);
    }
    let mut depth = 0usize;
    while !frontier.is_empty() {
        depth += 1;
        let mut next = Vec::new();
        for &u in &frontier {
            let (cols, _) = adj.row(u);
            for &v in cols {
                let v = v as usize;
                if levels[v] == usize::MAX {
                    levels[v] = depth;
                    next.push(v);
                }
            }
        }
        frontier = next;
    }
    levels
}

/// All-pairs shortest paths by tropical matrix squaring:
/// `D_{2k} = D_k ⊗ D_k (min,+)`, log₂(n) rounds. O(n³ log n) worst case —
/// for the small graphs of the examples/tests.
pub fn apsp_minplus(adj: &Csr, rounds: u32) -> Csr {
    // D₁ = A ⊕ I(0 diagonal) under min-plus
    let mut with_diag: Vec<(usize, usize, Value)> = (0..adj.rows).map(|i| (i, i, 0.0)).collect();
    for r in 0..adj.rows {
        let (cols, vals) = adj.row(r);
        for (c, v) in cols.iter().zip(vals) {
            if r != *c as usize {
                with_diag.push((r, *c as usize, *v));
            }
        }
    }
    // min-merge duplicates by construction: from_triplets sums, so build
    // manually via semiring ewise instead
    let mut d = Csr::from_triplets(adj.rows, adj.cols, vec![]);
    for (r, c, v) in with_diag {
        let single = Csr::from_triplets(adj.rows, adj.cols, vec![(r, c, v)]);
        d = ewise_add(&d, &single, MinPlus);
    }
    for _ in 0..rounds {
        let sq = spgemm_semiring(&d, &d, MinPlus);
        d = ewise_add(&d, &sq, MinPlus);
    }
    d
}

/// Transitive closure via boolean squaring (reachability matrix).
pub fn transitive_closure(adj: &Csr) -> Csr {
    let mut reach = Csr {
        data: adj.data.iter().map(|_| 1.0).collect(),
        ..adj.clone()
    };
    let rounds = crate::util::ilog2_ceil(adj.rows as u64) + 1;
    for _ in 0..rounds {
        let sq = spgemm_semiring(&reach, &reach, Boolean);
        let next = ewise_add(&reach, &sq, Boolean);
        if next.approx_same(&reach) {
            break;
        }
        reach = next;
    }
    reach
}

/// Triangle count of a simple undirected graph: tr(A³)/6 via one SpGEMM
/// plus a masked dot with A.
pub fn triangles(adj: &Csr) -> u64 {
    let a2 = spgemm_semiring(adj, adj, super::semiring::Arithmetic);
    let mut trace = 0.0;
    for i in 0..a2.rows {
        let (cols, vals) = a2.row(i);
        for (j, v) in cols.iter().zip(vals) {
            let (bc, bv) = adj.row(*j as usize);
            if let Ok(pos) = bc.binary_search(&(i as u32)) {
                trace += v * bv[pos];
            }
        }
    }
    (trace / 6.0).round() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Undirected path graph 0-1-2-3.
    fn path4() -> Csr {
        Csr::from_triplets(
            4,
            4,
            vec![
                (0, 1, 1.0),
                (1, 0, 1.0),
                (1, 2, 1.0),
                (2, 1, 1.0),
                (2, 3, 1.0),
                (3, 2, 1.0),
            ],
        )
    }

    #[test]
    fn bfs_on_path() {
        let levels = bfs_levels(&path4(), &[0]);
        assert_eq!(levels, vec![0, 1, 2, 3]);
        let multi = bfs_levels(&path4(), &[0, 3]);
        assert_eq!(multi, vec![0, 1, 1, 0]);
    }

    #[test]
    fn bfs_unreachable() {
        let a = Csr::from_triplets(3, 3, vec![(0, 1, 1.0)]);
        let levels = bfs_levels(&a, &[0]);
        assert_eq!(levels[2], usize::MAX);
    }

    #[test]
    fn apsp_on_weighted_path() {
        // 0 -2-> 1 -3-> 2
        let a = Csr::from_triplets(3, 3, vec![(0, 1, 2.0), (1, 2, 3.0)]);
        let d = apsp_minplus(&a, 2);
        let (cols, vals) = d.row(0);
        let pos = cols.iter().position(|&c| c == 2).unwrap();
        assert_eq!(vals[pos], 5.0);
        // diagonal is 0
        let dpos = cols.iter().position(|&c| c == 0).unwrap();
        assert_eq!(vals[dpos], 0.0);
    }

    #[test]
    fn closure_of_cycle_is_complete() {
        // directed 3-cycle: closure reaches everything
        let a = Csr::from_triplets(3, 3, vec![(0, 1, 1.0), (1, 2, 1.0), (2, 0, 1.0)]);
        let c = transitive_closure(&a);
        assert_eq!(c.nnz(), 9);
    }

    #[test]
    fn triangle_of_k3() {
        // complete graph on 3 vertices has exactly one triangle
        let mut tr = vec![];
        for i in 0..3usize {
            for j in 0..3usize {
                if i != j {
                    tr.push((i, j, 1.0));
                }
            }
        }
        let k3 = Csr::from_triplets(3, 3, tr);
        assert_eq!(triangles(&k3), 1);
        // path graph has none
        assert_eq!(triangles(&path4()), 0);
    }
}
