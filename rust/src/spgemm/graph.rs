//! Graph algorithms built on semiring SpGEMM — the applications of
//! §1.3/§1.4 (path-finding, BFS, graph analysis) expressed as the linear
//! algebra the thesis targets.
//!
//! Every algorithm exists in two forms:
//!
//! * **serial** ([`bfs_levels`], [`apsp_minplus`], [`transitive_closure`],
//!   [`triangles`]) — straight-line implementations over
//!   [`spgemm_semiring`] (or a direct frontier walk for BFS). These are
//!   the *bitwise oracles*.
//! * **served** ([`bfs_levels_served`], [`apsp_minplus_served`],
//!   [`transitive_closure_served`], [`triangles_served`]) — the same
//!   algorithms with every matrix product routed through the
//!   [`Coordinator`] as a [`crate::spgemm::Dataflow::ParGustavson`] job
//!   (built with the fluent [`Job::pair`] builder) carrying the right
//!   [`SemiringKind`]. The products run on the persistent worker
//!   pool with hybrid accumulators, and products over the *registered*
//!   adjacency pair share one cached symbolic plan — even across
//!   semirings, because plans are value-free. Results are identical to
//!   the serial oracles (bitwise for the CSR-valued algorithms).
//!
//! The served functions take `&mut Coordinator` plus the [`MatrixId`] of
//! a registered adjacency matrix and require exclusive use of the
//! coordinator (no other jobs in flight) — they submit and collect one
//! product at a time.
//!
//! Explicit stored zeros: the boolean semiring treats a stored `0.0` as
//! "no edge" (its ⊗ annihilates), and the serial oracles do the same, so
//! serial and served agree even on graphs with explicit zeros. BFS is the
//! one structural exception — like the classic frontier walk, it follows
//! every *stored* entry. Prune explicit zeros first
//! ([`Csr::prune_zeros`]) if that distinction matters for your graph.

use super::semiring::{ewise_add, spgemm_semiring, Boolean, MinPlus, SemiringKind};
use crate::coordinator::{Coordinator, Job, MatrixId, MatrixRef};
use crate::formats::{Csr, Value};
use std::sync::Arc;

// ---------------------------------------------------------------------------
// Shared building blocks (serial and served paths use the same ones, so
// the only difference between the two forms is *where* products execute).
// ---------------------------------------------------------------------------

/// `D₁` of the min-plus squaring: a zero diagonal plus every off-diagonal
/// adjacency entry (self-loops are superseded by the 0-cost diagonal).
fn minplus_init(adj: &Csr) -> Csr {
    let mut triplets: Vec<(usize, usize, Value)> = (0..adj.rows).map(|i| (i, i, 0.0)).collect();
    for r in 0..adj.rows {
        let (cols, vals) = adj.row(r);
        for (c, v) in cols.iter().zip(vals) {
            if r != *c as usize {
                triplets.push((r, *c as usize, *v));
            }
        }
    }
    Csr::from_triplets(adj.rows, adj.cols, triplets)
}

/// The boolean view of an adjacency matrix: every nonzero entry becomes
/// `1.0`, explicit stored zeros are dropped (boolean ⊗ annihilates on
/// them, so they are "no edge"). Keeping the matrix zero-free is what
/// lets the closure fixpoint test compare structurally — a zero-valued
/// entry flickering in and out of the union would never converge.
fn booleanize(adj: &Csr) -> Csr {
    let mut triplets = Vec::with_capacity(adj.nnz());
    for r in 0..adj.rows {
        let (cols, vals) = adj.row(r);
        for (c, v) in cols.iter().zip(vals) {
            if *v != 0.0 {
                triplets.push((r, *c as usize, 1.0));
            }
        }
    }
    Csr::from_triplets(adj.rows, adj.cols, triplets)
}

/// `tr(A² ⊙ Aᵀ)` — the masked dot step of the triangle count. `adj` must
/// be symmetric (simple undirected graph), so `Aᵀ = A`.
fn masked_trace(a2: &Csr, adj: &Csr) -> f64 {
    let mut trace = 0.0;
    for i in 0..a2.rows {
        let (cols, vals) = a2.row(i);
        for (j, v) in cols.iter().zip(vals) {
            let (bc, bv) = adj.row(*j as usize);
            if let Ok(pos) = bc.binary_search(&(i as u32)) {
                trace += v * bv[pos];
            }
        }
    }
    trace
}

/// Rounds after which repeated squaring must have reached the closure
/// fixpoint.
fn closure_rounds(n: usize) -> u32 {
    crate::util::ilog2_ceil(n as u64) + 1
}

// ---------------------------------------------------------------------------
// Serial oracles.
// ---------------------------------------------------------------------------

/// Multi-source BFS levels via a direct frontier walk — the serial oracle
/// of [`bfs_levels_served`]. Returns `levels[v] = hops from the nearest
/// source` (`usize::MAX` if unreachable).
pub fn bfs_levels(adj: &Csr, sources: &[usize]) -> Vec<usize> {
    let n = adj.rows;
    let mut levels = vec![usize::MAX; n];
    let mut frontier: Vec<usize> = Vec::new();
    for &s in sources {
        assert!(s < n);
        levels[s] = 0;
        frontier.push(s);
    }
    let mut depth = 0usize;
    while !frontier.is_empty() {
        depth += 1;
        let mut next = Vec::new();
        for &u in &frontier {
            let (cols, _) = adj.row(u);
            for &v in cols {
                let v = v as usize;
                if levels[v] == usize::MAX {
                    levels[v] = depth;
                    next.push(v);
                }
            }
        }
        frontier = next;
    }
    levels
}

/// All-pairs shortest paths by tropical matrix squaring:
/// `D_{2k} = D_k ⊗ D_k (min,+)`, `rounds` rounds — the serial oracle of
/// [`apsp_minplus_served`]. O(n³ log n) worst case; for the small graphs
/// of the examples/tests.
pub fn apsp_minplus(adj: &Csr, rounds: u32) -> Csr {
    let mut d = minplus_init(adj);
    for _ in 0..rounds {
        let sq = spgemm_semiring(&d, &d, MinPlus);
        d = ewise_add(&d, &sq, MinPlus);
    }
    d
}

/// Transitive closure via boolean squaring (reachability matrix) — the
/// serial oracle of [`transitive_closure_served`].
pub fn transitive_closure(adj: &Csr) -> Csr {
    let mut reach = booleanize(adj);
    for _ in 0..closure_rounds(adj.rows) {
        let sq = spgemm_semiring(&reach, &reach, Boolean);
        let next = ewise_add(&reach, &sq, Boolean);
        if next.approx_same(&reach) {
            break;
        }
        reach = next;
    }
    reach
}

/// Triangle count of a simple undirected graph: tr(A³)/6 via one SpGEMM
/// plus a masked dot with A — the serial oracle of [`triangles_served`].
pub fn triangles(adj: &Csr) -> u64 {
    let a2 = spgemm_semiring(adj, adj, super::semiring::Arithmetic);
    (masked_trace(&a2, adj) / 6.0).round() as u64
}

// ---------------------------------------------------------------------------
// Served variants: every product goes through the Coordinator onto the
// parallel backend (worker pool, hybrid accumulators, cached plans).
// ---------------------------------------------------------------------------

/// Submit one semiring SpGEMM job and wait for its product. Requires
/// exclusive use of the coordinator: with foreign jobs in flight the
/// response collected here could be someone else's.
fn served_spgemm(
    coord: &mut Coordinator,
    a: MatrixRef,
    b: MatrixRef,
    kind: SemiringKind,
    threads: usize,
) -> Csr {
    assert_eq!(
        coord.pending(),
        0,
        "served graph algorithms need exclusive use of the coordinator"
    );
    let id = coord
        .try_submit(Job::pair(a, b).threads(threads).semiring(kind))
        .expect("graph jobs run against an unbounded default tenant");
    let r = coord.collect_one().expect("graph job outstanding");
    debug_assert_eq!(r.id, id, "exclusive use violated");
    r.c
}

/// Pointer clone of a registered adjacency matrix, or a panic naming the
/// caller's contract.
fn registered(coord: &Coordinator, adj: MatrixId) -> Arc<Csr> {
    coord
        .matrix(adj)
        .expect("graph adjacency must be registered with the coordinator")
}

/// [`bfs_levels`] on the served fast path, with batched multi-source
/// frontiers: the distinct sources run one independent BFS each, but
/// every level expands ALL of them with a single served `F ⊗ A` boolean
/// product, where `F` is a k×n frontier matrix (one row per source).
/// One job per level — not one per source per level — so a k-source
/// traversal costs the same number of round-trips through the
/// coordinator as a single-source one. The merged result takes the
/// per-vertex minimum across sources, which is exactly the serial
/// oracle's "hops from the nearest source". The adjacency is the
/// registered resident; frontier matrices are one-shot inline operands.
pub fn bfs_levels_served(
    coord: &mut Coordinator,
    adj: MatrixId,
    sources: &[usize],
    threads: usize,
) -> Vec<usize> {
    let n = registered(coord, adj).rows;
    // Deduplicate sources: a repeated source would add an identical BFS
    // row (pure waste) without changing the min-merge.
    let mut distinct: Vec<usize> = Vec::new();
    for &s in sources {
        assert!(s < n);
        if !distinct.contains(&s) {
            distinct.push(s);
        }
    }
    let k = distinct.len();
    let mut levels = vec![vec![usize::MAX; n]; k];
    let mut frontiers: Vec<Vec<usize>> = distinct.iter().map(|&s| vec![s]).collect();
    for (lv, &s) in levels.iter_mut().zip(&distinct) {
        lv[s] = 0;
    }
    let mut depth = 0usize;
    while frontiers.iter().any(|f| !f.is_empty()) {
        depth += 1;
        let f = Csr::from_triplets(
            k,
            n,
            frontiers
                .iter()
                .enumerate()
                .flat_map(|(r, fr)| fr.iter().map(move |&c| (r, c, 1.0))),
        );
        let next = served_spgemm(coord, f.into(), adj.into(), SemiringKind::Boolean, threads);
        for (r, (fr, lv)) in frontiers.iter_mut().zip(levels.iter_mut()).enumerate() {
            fr.clear();
            let (cols, _) = next.row(r);
            for &j in cols {
                let j = j as usize;
                if lv[j] == usize::MAX {
                    lv[j] = depth;
                    fr.push(j);
                }
            }
        }
    }
    let mut merged = vec![usize::MAX; n];
    for lv in &levels {
        for (m, &l) in merged.iter_mut().zip(lv) {
            *m = (*m).min(l);
        }
    }
    merged
}

/// [`apsp_minplus`] on the served fast path: each squaring round is a
/// `D ⊗ D` min-plus job (inline — `D` changes every round); the cheap
/// O(nnz) ⊕-union with the previous `D` stays on the caller's thread.
pub fn apsp_minplus_served(
    coord: &mut Coordinator,
    adj: MatrixId,
    rounds: u32,
    threads: usize,
) -> Csr {
    let adj_m = registered(coord, adj);
    let mut d = minplus_init(&adj_m);
    for _ in 0..rounds {
        let da = Arc::new(d);
        let sq = served_spgemm(
            coord,
            Arc::clone(&da).into(),
            Arc::clone(&da).into(),
            SemiringKind::MinPlus,
            threads,
        );
        d = ewise_add(&da, &sq, MinPlus);
    }
    d
}

/// [`transitive_closure`] on the served fast path. The first squaring
/// runs on the *registered* adjacency pair — boolean ⊗ only reads
/// nonzero-ness, so after pruning the (structural) zero-valued entries a
/// raw-adjacency square equals the booleanized square — and therefore
/// shares the coordinator's cached `(adj, adj)` symbolic plan with any
/// other same-pair job, whatever its semiring (e.g. a
/// [`triangles_served`] call). Later rounds square the evolving
/// reachability matrix inline (zero-free by construction, so no pruning
/// is needed there).
pub fn transitive_closure_served(coord: &mut Coordinator, adj: MatrixId, threads: usize) -> Csr {
    let adj_m = registered(coord, adj);
    let mut reach = Arc::new(booleanize(&adj_m));
    for round in 0..closure_rounds(adj_m.rows) {
        let sq = if round == 0 {
            let sq = served_spgemm(coord, adj.into(), adj.into(), SemiringKind::Boolean, threads);
            // A product through an explicit-zero edge is a stored 0.0 in
            // the structural output; drop it — `booleanize` dropped the
            // edge itself on the serial side.
            sq.prune_zeros()
        } else {
            served_spgemm(
                coord,
                Arc::clone(&reach).into(),
                Arc::clone(&reach).into(),
                SemiringKind::Boolean,
                threads,
            )
        };
        let next = ewise_add(&reach, &sq, Boolean);
        if next.approx_same(&reach) {
            break;
        }
        reach = Arc::new(next);
    }
    Arc::try_unwrap(reach).unwrap_or_else(|r| (*r).clone())
}

/// [`triangles`] on the served fast path: `A²` is one arithmetic job on
/// the registered pair (plan-cached and shared with any other `(adj,
/// adj)` job); the masked trace stays on the caller's thread.
pub fn triangles_served(coord: &mut Coordinator, adj: MatrixId, threads: usize) -> u64 {
    let a2 = served_spgemm(coord, adj.into(), adj.into(), SemiringKind::Arithmetic, threads);
    let adj_m = registered(coord, adj);
    (masked_trace(&a2, &adj_m) / 6.0).round() as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ServerConfig;
    use crate::gen::{banded, rmat, undirected, RmatParams};

    /// Undirected path graph 0-1-2-3.
    fn path4() -> Csr {
        Csr::from_triplets(
            4,
            4,
            vec![
                (0, 1, 1.0),
                (1, 0, 1.0),
                (1, 2, 1.0),
                (2, 1, 1.0),
                (2, 3, 1.0),
                (3, 2, 1.0),
            ],
        )
    }

    #[test]
    fn bfs_on_path() {
        let levels = bfs_levels(&path4(), &[0]);
        assert_eq!(levels, vec![0, 1, 2, 3]);
        let multi = bfs_levels(&path4(), &[0, 3]);
        assert_eq!(multi, vec![0, 1, 1, 0]);
    }

    #[test]
    fn bfs_unreachable() {
        let a = Csr::from_triplets(3, 3, vec![(0, 1, 1.0)]);
        let levels = bfs_levels(&a, &[0]);
        assert_eq!(levels[2], usize::MAX);
    }

    #[test]
    fn apsp_on_weighted_path() {
        // 0 -2-> 1 -3-> 2
        let a = Csr::from_triplets(3, 3, vec![(0, 1, 2.0), (1, 2, 3.0)]);
        let d = apsp_minplus(&a, 2);
        let (cols, vals) = d.row(0);
        let pos = cols.iter().position(|&c| c == 2).unwrap();
        assert_eq!(vals[pos], 5.0);
        // diagonal is 0
        let dpos = cols.iter().position(|&c| c == 0).unwrap();
        assert_eq!(vals[dpos], 0.0);
    }

    #[test]
    fn closure_of_cycle_is_complete() {
        // directed 3-cycle: closure reaches everything
        let a = Csr::from_triplets(3, 3, vec![(0, 1, 1.0), (1, 2, 1.0), (2, 0, 1.0)]);
        let c = transitive_closure(&a);
        assert_eq!(c.nnz(), 9);
    }

    #[test]
    fn triangle_of_k3() {
        // complete graph on 3 vertices has exactly one triangle
        let mut tr = vec![];
        for i in 0..3usize {
            for j in 0..3usize {
                if i != j {
                    tr.push((i, j, 1.0));
                }
            }
        }
        let k3 = Csr::from_triplets(3, 3, tr);
        assert_eq!(triangles(&k3), 1);
        // path graph has none
        assert_eq!(triangles(&path4()), 0);
    }

    /// Served == serial on rmat and banded inputs: BFS levels, APSP
    /// values (bitwise), closure (bitwise), and triangle counts.
    #[test]
    fn served_matches_serial_oracles() {
        let inputs: Vec<(&str, Csr)> = vec![
            ("rmat", undirected(&rmat(&RmatParams::new(7, 500, 31)))),
            ("banded", undirected(&banded(96, 3, 32))),
        ];
        for (name, adj) in &inputs {
            let mut coord = Coordinator::start(ServerConfig {
                workers: 2,
                queue_depth: 8,
                ..ServerConfig::default()
            });
            let id = coord.register("adjacency", adj.clone());

            let levels = bfs_levels_served(&mut coord, id, &[0], 2);
            assert_eq!(levels, bfs_levels(adj, &[0]), "{name}: BFS levels");

            let d_served = apsp_minplus_served(&mut coord, id, 3, 2);
            let d_serial = apsp_minplus(adj, 3);
            assert_eq!(d_served.row_ptr, d_serial.row_ptr, "{name}: APSP shape");
            assert_eq!(d_served.col_idx, d_serial.col_idx, "{name}: APSP cols");
            assert_eq!(d_served.data, d_serial.data, "{name}: APSP values");

            let tc_served = transitive_closure_served(&mut coord, id, 2);
            let tc_serial = transitive_closure(adj);
            assert_eq!(tc_served.row_ptr, tc_serial.row_ptr, "{name}: closure");
            assert_eq!(tc_served.col_idx, tc_serial.col_idx, "{name}: closure");
            assert_eq!(tc_served.data, tc_serial.data, "{name}: closure");

            assert_eq!(
                triangles_served(&mut coord, id, 2),
                triangles(adj),
                "{name}: triangles"
            );
            coord.shutdown();
        }
    }

    /// The mixed-semiring plan-sharing contract: triangle counting
    /// (arithmetic) and the closure's first squaring (boolean) both run
    /// on the registered `(adj, adj)` pair and must share ONE cached
    /// symbolic plan.
    #[test]
    fn same_pair_jobs_share_plan_across_semirings() {
        let adj = undirected(&rmat(&RmatParams::new(6, 220, 41)));
        let mut coord = Coordinator::start(ServerConfig {
            workers: 2,
            queue_depth: 8,
            ..ServerConfig::default()
        });
        let id = coord.register("adjacency", adj.clone());
        let tri = triangles_served(&mut coord, id, 2);
        let tc = transitive_closure_served(&mut coord, id, 2);
        assert_eq!(tri, triangles(&adj));
        assert!(tc.nnz() >= adj.nnz());
        let (passes, hits) = coord.symbolic_stats();
        assert_eq!(
            passes, 1,
            "arithmetic A² and boolean A⊗A must share one symbolic pass"
        );
        assert!(hits >= 1, "the cross-semiring reuse must register as a hit");
        coord.shutdown();
    }

    /// Explicit stored-zero edges are "no edge" to the closure (boolean
    /// ⊗ annihilates on them): the fixpoint converges instead of
    /// oscillating on the structural zero, and served == serial bitwise.
    #[test]
    fn closure_treats_stored_zero_edges_as_absent() {
        let a = Csr::from_triplets(3, 3, vec![(0, 1, 0.0), (1, 2, 1.0)]);
        assert_eq!(a.nnz(), 2, "the zero edge must be stored for this test");
        let tc = transitive_closure(&a);
        assert_eq!(tc.nnz(), 1, "only the real 1->2 edge is reachable");
        assert_eq!(tc.row(1).0, &[2]);
        let mut coord = Coordinator::start(ServerConfig {
            workers: 1,
            queue_depth: 4,
            ..ServerConfig::default()
        });
        let id = coord.register("adjacency", a.clone());
        let served = transitive_closure_served(&mut coord, id, 2);
        assert_eq!(served.row_ptr, tc.row_ptr);
        assert_eq!(served.col_idx, tc.col_idx);
        assert_eq!(served.data, tc.data);
        coord.shutdown();
    }

    /// Batched multi-source BFS: k sources traverse as one k-row frontier
    /// matrix per level, and the min-merged levels equal the serial
    /// multi-source oracle on graphs where the sources' BFS trees overlap,
    /// run to different depths, and leave vertices unreachable.
    #[test]
    fn served_multi_source_bfs_matches_serial() {
        let cases: Vec<(&str, Csr, Vec<usize>)> = vec![
            ("path-ends", path4(), vec![0, 3]),
            ("rmat", undirected(&rmat(&RmatParams::new(7, 420, 33))), vec![0, 17, 63, 5]),
            ("banded", undirected(&banded(80, 2, 35)), vec![79, 0, 40]),
        ];
        for (name, adj, sources) in &cases {
            let mut coord = Coordinator::start(ServerConfig {
                workers: 2,
                queue_depth: 8,
                ..ServerConfig::default()
            });
            let id = coord.register("adjacency", adj.clone());
            let served = bfs_levels_served(&mut coord, id, sources, 2);
            assert_eq!(served, bfs_levels(adj, sources), "{name}");
            // sanity: the merged result really is nearest-source hops
            for &s in sources {
                assert_eq!(served[s], 0, "{name}: source level");
            }
            coord.shutdown();
        }
    }

    /// Serial BFS on a disconnected multi-source graph equals served BFS
    /// (exercises the empty-frontier and duplicate-source edges).
    #[test]
    fn served_bfs_edge_cases() {
        let a = Csr::from_triplets(5, 5, vec![(0, 1, 1.0), (3, 4, 1.0)]);
        let mut coord = Coordinator::start(ServerConfig {
            workers: 1,
            queue_depth: 4,
            ..ServerConfig::default()
        });
        let id = coord.register("adjacency", a.clone());
        let served = bfs_levels_served(&mut coord, id, &[0, 0, 3], 2);
        assert_eq!(served, bfs_levels(&a, &[0, 0, 3]));
        assert_eq!(served[2], usize::MAX);
        coord.shutdown();
    }
}
