//! Arithmetic intensity of SpGEMM (thesis §6.2, Eq. 6.1/6.2):
//!
//! `cf = flop / nnz(C)` and
//! `AI ≤ nnz(C)·cf / ((nnz(A)+nnz(B)+nnz(C))·b) ≤ cf / b`.

use super::total_flops;
use crate::formats::Csr;

/// cf — "compression factor": FMAs per output non-zero (Eq. 6.2).
pub fn compression_factor(flops: u64, c_nnz: usize) -> f64 {
    if c_nnz == 0 {
        return 0.0;
    }
    flops as f64 / c_nnz as f64
}

/// AI — flops per byte moved (Eq. 6.1). `elem_bytes` is `b` in the paper
/// (8 for doubles).
pub fn arithmetic_intensity(
    flops: u64,
    a_nnz: usize,
    b_nnz: usize,
    c_nnz: usize,
    elem_bytes: usize,
) -> f64 {
    let moved = (a_nnz + b_nnz + c_nnz) as f64 * elem_bytes as f64;
    if moved == 0.0 {
        return 0.0;
    }
    flops as f64 / moved
}

/// Full §6.2 report for a multiplication instance.
#[derive(Clone, Copy, Debug)]
pub struct IntensityReport {
    pub a_nnz: usize,
    pub b_nnz: usize,
    pub c_nnz: usize,
    pub flops: u64,
    pub cf: f64,
    pub ai: f64,
}

impl IntensityReport {
    /// Compute cf/AI for C = A·B, with C's structure from the symbolic pass.
    pub fn of(a: &Csr, b: &Csr, c_nnz: usize) -> Self {
        let flops = total_flops(a, b);
        let cf = compression_factor(flops, c_nnz);
        let ai = arithmetic_intensity(flops, a.nnz(), b.nnz(), c_nnz, 8);
        Self {
            a_nnz: a.nnz(),
            b_nnz: b.nnz(),
            c_nnz,
            flops,
            cf,
            ai,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_values() {
        // Table 6.1 / §6.2: nnz(A)=nnz(B)=254211, nnz(C)=5174841,
        // cf = 1.23 => flop ≈ 6.365e6; AI ≈ 0.14 by the formula with b=8...
        // The thesis quotes AI=0.09 for its V3 implementation (which also
        // moves hashtable traffic); the *upper bound* from Eq 6.1 is cf/b.
        let flops = (1.23f64 * 5_174_841.0) as u64;
        let cf = compression_factor(flops, 5_174_841);
        assert!((cf - 1.23).abs() < 0.01);
        let ai = arithmetic_intensity(flops, 254_211, 254_211, 5_174_841, 8);
        assert!(ai <= cf / 8.0 + 1e-12, "AI={} must be <= cf/b={}", ai, cf / 8.0);
        assert!(ai > 0.1 && ai < 0.16, "AI={ai}");
    }

    #[test]
    fn degenerate() {
        assert_eq!(compression_factor(0, 0), 0.0);
        assert_eq!(arithmetic_intensity(10, 0, 0, 0, 8), 0.0);
    }
}
