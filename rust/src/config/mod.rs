//! Configuration system: simulator target configuration (the paper's
//! Table 4.2), kernel tuning knobs, and a tiny `key = value` config-file
//! parser (serde is unavailable offline).

mod parse;

pub use parse::{parse_kv, ConfigError};

/// Target-architecture configuration for one simulated PIUMA block,
/// mirroring Table 4.2 of the thesis plus latency knobs (Table 4.2 lists
/// structure; latencies are the interval-model parameters).
#[derive(Clone, Debug, PartialEq)]
pub struct SimConfig {
    // ---- topology (Table 4.2) ----
    /// Number of blocks ("cores" in Table 4.2 wording) per die.
    pub blocks: usize,
    /// Multi-threaded cores per block.
    pub mtc_per_block: usize,
    /// Hardware thread contexts per MTC (register-file depth).
    pub threads_per_mtc: usize,
    /// Single-threaded cores per block (memory/thread management).
    pub stc_per_block: usize,

    // ---- memories ----
    /// Scratchpad size per block, bytes (Table 4.2: 4096 KB).
    pub spad_bytes: usize,
    /// L1 cache size per core, bytes (Table 4.2: 16 KB).
    pub l1_bytes: usize,
    /// L1 associativity (Table 4.2: 4).
    pub l1_assoc: usize,
    /// L1 line size, bytes (Table 4.2: 64).
    pub l1_line: usize,

    // ---- interval-model latencies (cycles) ----
    /// ALU / integer op.
    pub lat_alu: u64,
    /// L1 hit.
    pub lat_l1_hit: u64,
    /// DRAM access (load miss fill / uncached 8-byte native access).
    pub lat_dram: u64,
    /// SPAD access.
    pub lat_spad: u64,
    /// Atomic op on SPAD (uncontended).
    pub lat_atomic_spad: u64,
    /// Block-wide SPAD atomic-unit throughput: cycles per atomic
    /// (fractional — the SPAD is banked). The serializing resource the
    /// V1/V2 hashing phases queue on.
    pub spad_atomic_service: f64,
    /// Atomic op on DRAM (uncontended, via memory controller).
    pub lat_atomic_dram: u64,
    /// Extra serialization penalty per concurrent contender on the same
    /// atomic line.
    pub lat_atomic_contention: u64,
    /// One-way network hop for a remote instruction packet.
    pub lat_remote_packet: u64,
    /// Token-pool poll (producer-consumer dynamic scheduling).
    pub lat_token_poll: u64,
    /// Barrier entry overhead per thread.
    pub lat_barrier: u64,

    // ---- bandwidth model ----
    /// Core clock in GHz (used to convert cycles <-> seconds).
    pub clock_ghz: f64,
    /// Peak DRAM bandwidth per block, GB/s.
    pub dram_peak_gbs: f64,
    /// DMA engine sustained share of DRAM bandwidth [0,1].
    pub dma_bw_share: f64,
    /// Memory controllers support native 8-byte accesses (PIUMA §4.1.3);
    /// if false, every DRAM access fetches a full line.
    pub native_8b_dram: bool,

    /// Utilization-timeline sample period in cycles (metrics granularity).
    pub timeline_sample_cycles: u64,
    /// Capture an instruction trace (see `sim::trace`) — memory-heavy;
    /// meant for window-scoped runs and the replay regression harness.
    pub trace: bool,
}

impl SimConfig {
    /// The paper's simulated target: one PIUMA block (Table 4.2 row
    /// "Core Count = varying", we default to 1 block of 4 MTC x 16 threads
    /// = 64 threads, the Table 6.7 configuration).
    pub fn piuma_block() -> Self {
        Self {
            blocks: 1,
            mtc_per_block: 4,
            threads_per_mtc: 16,
            stc_per_block: 2,
            spad_bytes: 4096 * 1024,
            l1_bytes: 16 * 1024,
            l1_assoc: 4,
            l1_line: 64,
            lat_alu: 1,
            lat_l1_hit: 1,
            lat_dram: 90,
            lat_spad: 4,
            lat_atomic_spad: 6,
            spad_atomic_service: 0.5,
            lat_atomic_dram: 100,
            lat_atomic_contention: 8,
            lat_remote_packet: 40,
            lat_token_poll: 12,
            lat_barrier: 16,
            clock_ghz: 1.0,
            dram_peak_gbs: 5.486,
            dma_bw_share: 0.5,
            native_8b_dram: true,
            timeline_sample_cycles: 10_000,
            trace: false,
        }
    }

    /// Smaller config for fast unit tests (fewer threads, tiny SPAD).
    pub fn test_tiny() -> Self {
        Self {
            blocks: 1,
            mtc_per_block: 2,
            threads_per_mtc: 4,
            stc_per_block: 1,
            spad_bytes: 64 * 1024,
            l1_bytes: 4 * 1024,
            l1_assoc: 2,
            l1_line: 64,
            timeline_sample_cycles: 1_000,
            ..Self::piuma_block()
        }
    }

    /// Multi-block scale-out config (window scheduling across blocks).
    pub fn piuma_die(blocks: usize) -> Self {
        Self {
            blocks,
            ..Self::piuma_block()
        }
    }

    /// Total MTC threads per block (the "64 PIUMA threads" of Table 6.7).
    pub fn threads_per_block(&self) -> usize {
        self.mtc_per_block * self.threads_per_mtc
    }

    /// Cycles per second.
    pub fn hz(&self) -> f64 {
        self.clock_ghz * 1e9
    }

    /// Convert a cycle count to milliseconds.
    pub fn cycles_to_ms(&self, cycles: u64) -> f64 {
        cycles as f64 / self.hz() * 1e3
    }

    /// DRAM peak bytes/cycle.
    pub fn dram_bytes_per_cycle(&self) -> f64 {
        self.dram_peak_gbs * 1e9 / self.hz()
    }

    /// Apply `key = value` overrides (e.g. from a config file or CLI).
    pub fn apply_override(&mut self, key: &str, value: &str) -> Result<(), ConfigError> {
        macro_rules! set {
            ($field:ident) => {
                self.$field = value.parse().map_err(|_| ConfigError::BadValue {
                    key: key.into(),
                    value: value.into(),
                })?
            };
        }
        match key {
            "blocks" => set!(blocks),
            "mtc_per_block" => set!(mtc_per_block),
            "threads_per_mtc" => set!(threads_per_mtc),
            "stc_per_block" => set!(stc_per_block),
            "spad_bytes" => set!(spad_bytes),
            "l1_bytes" => set!(l1_bytes),
            "l1_assoc" => set!(l1_assoc),
            "l1_line" => set!(l1_line),
            "lat_alu" => set!(lat_alu),
            "lat_l1_hit" => set!(lat_l1_hit),
            "lat_dram" => set!(lat_dram),
            "lat_spad" => set!(lat_spad),
            "lat_atomic_spad" => set!(lat_atomic_spad),
            "spad_atomic_service" => set!(spad_atomic_service),
            "lat_atomic_dram" => set!(lat_atomic_dram),
            "lat_atomic_contention" => set!(lat_atomic_contention),
            "lat_remote_packet" => set!(lat_remote_packet),
            "lat_token_poll" => set!(lat_token_poll),
            "lat_barrier" => set!(lat_barrier),
            "clock_ghz" => set!(clock_ghz),
            "dram_peak_gbs" => set!(dram_peak_gbs),
            "dma_bw_share" => set!(dma_bw_share),
            "native_8b_dram" => set!(native_8b_dram),
            "timeline_sample_cycles" => set!(timeline_sample_cycles),
            "trace" => set!(trace),
            _ => {
                return Err(ConfigError::UnknownKey { key: key.into() });
            }
        }
        Ok(())
    }

    /// Load a config file of `key = value` lines over a preset base.
    pub fn from_file(path: &str, base: SimConfig) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path).map_err(|e| ConfigError::Io {
            path: path.into(),
            msg: e.to_string(),
        })?;
        let mut cfg = base;
        for (k, v) in parse_kv(&text)? {
            cfg.apply_override(&k, &v)?;
        }
        Ok(cfg)
    }
}

impl Default for SimConfig {
    fn default() -> Self {
        Self::piuma_block()
    }
}

/// Hashing strategy for the SMASH hashtable (V1 uses high-order bits,
/// V2/V3 use low-order bits — §5.1.2 / §5.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HashBits {
    /// Hash on high-order bits (preserves sort order, clusters collide).
    High,
    /// Hash on low-order bits (spreads clusters, order not preserved).
    Low,
}

/// Work-allocation strategy across MTC threads (§5.1 vs §5.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheduling {
    /// V1: rows statically assigned round-robin.
    StaticRoundRobin,
    /// V2/V3: producer-consumer token pool, two tokens (even/odd half) per row.
    Tokenized,
}

/// Where the hashtable lives (§5.1 vs §5.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TablePlacement {
    /// V1/V2: tag+data hashtable in scratchpad.
    Spad,
    /// V3: tag->offset hashtable in DRAM; dense tag/value arrays in SPAD,
    /// streamed out by the DMA engine.
    DramFragmented,
}

/// Tuning knobs of the SMASH kernels (Ch. 5).
#[derive(Clone, Debug)]
pub struct KernelConfig {
    pub hash_bits: HashBits,
    pub scheduling: Scheduling,
    pub placement: TablePlacement,
    /// Rows with more FMAs than this are treated as "dense rows" in window
    /// planning (§5.1.1 threshold).
    pub dense_row_threshold: usize,
    /// Hashtable load-factor target: bins = next_pow2(est_nnz / load).
    pub table_load_factor: f64,
    /// Tokens generated per row (2 = paper's even/odd split).
    pub tokens_per_row: usize,
    /// Use the DMA engine for SPAD->DRAM writeback (V3).
    pub use_dma: bool,
    /// Hash into a *remote* block's SPAD via network instructions
    /// (§4.1.2.2: "we make use of remote atomics in our algorithm to
    /// update the partial products in our hash table"). Models the
    /// distributed-hashtable variant where a fraction
    /// `(blocks-1)/blocks` of upserts cross the fabric; 0 = all-local
    /// (the windowed design). Ablation knob.
    pub remote_table_blocks: usize,
}

impl KernelConfig {
    /// SMASH V1 — §5.1: static allocation, high-bit hashing, SPAD table.
    /// V1 runs at a lower table load factor: high-bit hashing aliases hub
    /// columns into shared bins (the §7.2 hotspot pathology), so it needs
    /// spare slots to keep the walk bounded (0.5 load explodes to >500
    /// probes/upsert on R-MAT inputs; 0.25 keeps it near 10).
    pub fn v1() -> Self {
        Self {
            hash_bits: HashBits::High,
            scheduling: Scheduling::StaticRoundRobin,
            placement: TablePlacement::Spad,
            dense_row_threshold: 1024,
            table_load_factor: 0.25,
            tokens_per_row: 1,
            use_dma: false,
            remote_table_blocks: 0,
        }
    }

    /// SMASH V2 — §5.2: tokenization, low-bit hashing, SPAD table.
    pub fn v2() -> Self {
        Self {
            hash_bits: HashBits::Low,
            scheduling: Scheduling::Tokenized,
            tokens_per_row: 2,
            table_load_factor: 0.9,
            ..Self::v1()
        }
    }

    /// SMASH V3 — §5.3: V2 + DRAM tag-offset table + dense SPAD arrays + DMA.
    pub fn v3() -> Self {
        Self {
            placement: TablePlacement::DramFragmented,
            use_dma: true,
            ..Self::v2()
        }
    }

    pub fn name(&self) -> &'static str {
        match (self.placement, self.scheduling) {
            (TablePlacement::DramFragmented, _) => "SMASH-V3",
            (TablePlacement::Spad, Scheduling::Tokenized) => "SMASH-V2",
            (TablePlacement::Spad, Scheduling::StaticRoundRobin) => "SMASH-V1",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn piuma_block_matches_table_4_2() {
        let c = SimConfig::piuma_block();
        assert_eq!(c.mtc_per_block, 4);
        assert_eq!(c.stc_per_block, 2);
        assert_eq!(c.threads_per_mtc, 16);
        assert_eq!(c.threads_per_block(), 64); // Table 6.7: 64 PIUMA threads
        assert_eq!(c.spad_bytes, 4096 * 1024);
        assert_eq!(c.l1_bytes, 16 * 1024);
        assert_eq!(c.l1_assoc, 4);
        assert_eq!(c.l1_line, 64);
    }

    #[test]
    fn cycle_conversions() {
        let c = SimConfig::piuma_block();
        assert!((c.cycles_to_ms(1_000_000) - 1.0).abs() < 1e-9);
        assert!(c.dram_bytes_per_cycle() > 5.0);
    }

    #[test]
    fn overrides() {
        let mut c = SimConfig::piuma_block();
        c.apply_override("lat_dram", "120").unwrap();
        assert_eq!(c.lat_dram, 120);
        assert!(c.apply_override("nope", "1").is_err());
        assert!(c.apply_override("lat_dram", "abc").is_err());
    }

    #[test]
    fn version_names() {
        assert_eq!(KernelConfig::v1().name(), "SMASH-V1");
        assert_eq!(KernelConfig::v2().name(), "SMASH-V2");
        assert_eq!(KernelConfig::v3().name(), "SMASH-V3");
    }
}
