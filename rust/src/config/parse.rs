//! Minimal `key = value` config parser (comments with `#`, blank lines
//! ignored, optional `[section]` headers flattened as `section.key`).

use std::fmt;

#[derive(Debug)]
pub enum ConfigError {
    Syntax { line: usize, text: String },
    UnknownKey { key: String },
    BadValue { key: String, value: String },
    Io { path: String, msg: String },
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConfigError::Syntax { line, text } => {
                write!(f, "config line {line}: expected `key = value`, got `{text}`")
            }
            ConfigError::UnknownKey { key } => write!(f, "unknown config key `{key}`"),
            ConfigError::BadValue { key, value } => {
                write!(f, "bad value `{value}` for key `{key}`")
            }
            ConfigError::Io { path, msg } => write!(f, "cannot read config `{path}`: {msg}"),
        }
    }
}

impl std::error::Error for ConfigError {}

/// Parse `key = value` lines into pairs. Section headers prefix subsequent
/// keys with `section.`.
pub fn parse_kv(text: &str) -> Result<Vec<(String, String)>, ConfigError> {
    let mut out = Vec::new();
    let mut section = String::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') && line.ends_with(']') {
            section = line[1..line.len() - 1].trim().to_string();
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            return Err(ConfigError::Syntax {
                line: i + 1,
                text: raw.to_string(),
            });
        };
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{section}.{}", k.trim())
        };
        out.push((key, v.trim().trim_matches('"').to_string()));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_pairs_and_sections() {
        let text = "\n# comment\na = 1\n[sim]\nlat_dram = 90 # inline\nname = \"x\"\n";
        let kv = parse_kv(text).unwrap();
        assert_eq!(
            kv,
            vec![
                ("a".into(), "1".into()),
                ("sim.lat_dram".into(), "90".into()),
                ("sim.name".into(), "x".into()),
            ]
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_kv("what is this").is_err());
    }
}
