//! Regenerates Figure 1.1: the GCN kernel execution-time breakdown that
//! motivates the thesis (SpGEMM dominating a GCN forward pass), measured
//! on our decomposed GCN pipeline, plus the AOT-artifact end-to-end
//! latency when `artifacts/` exist.

use smash::report::bar_chart;
use smash::runtime::{gcn::DIMS, GcnModel, GcnWorkload};

fn main() {
    println!("# Figure 1.1 — GCN kernel execution time breakdown\n");
    let w = GcnWorkload::synthetic(DIMS, 7);

    // average the shares over a few repetitions for stability
    let reps = 5;
    let mut acc: Vec<(String, f64)> = Vec::new();
    for _ in 0..reps {
        for (i, (name, share)) in w.kernel_breakdown().into_iter().enumerate() {
            if acc.len() <= i {
                acc.push((name, 0.0));
            }
            acc[i].1 += share / reps as f64;
        }
    }
    println!("{}", bar_chart("GCN forward pass time shares", &acc, 50));
    let spgemm_share: f64 = acc
        .iter()
        .filter(|(n, _)| n.starts_with("SpGEMM"))
        .map(|(_, s)| s)
        .sum();
    println!(
        "SpGEMM share of the forward pass: {:.1}% (the paper's Fig 1.1 shows SpGEMM dominating)\n",
        spgemm_share * 100.0
    );

    // Optional: the fused AOT artifact end-to-end (needs `make artifacts`).
    match GcnModel::load() {
        Ok(mut model) => {
            let t0 = std::time::Instant::now();
            let n = 10;
            for _ in 0..n {
                model.forward(&w).expect("forward");
            }
            println!(
                "fused AOT artifact (PJRT): {:.2?} / inference over {n} runs",
                t0.elapsed() / n
            );
        }
        Err(e) => println!("(skipping AOT latency: {e})"),
    }
}
