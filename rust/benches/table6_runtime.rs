//! Regenerates Tables 6.1–6.7 of the thesis: data characteristics, CSR
//! footprints, DRAM bandwidth, L1 hit rates, aggregate IPC, and the
//! headline runtime/speedup comparison, on the §6.1 R-MAT workload.
//!
//! `SMASH_BENCH_SCALE=full` runs the thesis' 16K×16K operating point
//! (slower); the default small scale keeps the same skew at 2K.

use smash::bench::{self, Scale};
use smash::util::timer::time;

fn main() {
    let scale = match std::env::var("SMASH_BENCH_SCALE").as_deref() {
        Ok("full") => Scale::Full,
        _ => Scale::Small,
    };
    println!("# Tables 6.1-6.7 (scale {scale:?})\n");

    let ((a, b), gen_dt) = time(|| bench::paper_inputs(scale));
    println!("inputs generated in {gen_dt:.2?}\n");

    let (t61, intensity) = bench::table_6_1(&a, &b);
    println!("{}", t61.render());
    println!(
        "compression factor cf = {:.2} (paper: 1.23); arithmetic intensity AI = {:.3} (paper: 0.09)\n",
        intensity.cf, intensity.ai
    );

    let (t62, t63) = bench::table_6_2_6_3(&a, &b);
    println!("{}", t62.render());
    println!("{}", t63.render());

    let (reports, eval_dt) = time(|| {
        smash::kernels::run_all_versions(&a, &b, &smash::config::SimConfig::piuma_block())
    });
    println!("three SMASH versions simulated in {eval_dt:.2?}\n");
    println!("{}", bench::table_6_4(&reports).render());
    println!("{}", bench::table_6_5(&reports).render());
    println!("{}", bench::table_6_6(&reports).render());
    println!("{}", bench::table_6_7(&reports).render());
}
