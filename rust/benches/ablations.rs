//! Ablation benches for the design choices DESIGN.md calls out: each knob
//! of the SMASH configuration is flipped in isolation on the same workload
//! so its contribution to the V1->V3 speedup is visible.
//!
//! * hash bits: high (V1) vs low/scrambled (V2) at fixed scheduling;
//! * scheduling: static vs tokenized at fixed hashing;
//! * table placement: SPAD vs DRAM-fragmented (+DMA) at fixed scheduling;
//! * table load factor sweep (probe count vs window count trade-off);
//! * tokens per row (1 vs 2 vs 4).

use smash::config::{HashBits, KernelConfig, Scheduling, SimConfig, TablePlacement};
use smash::gen::{rmat, RmatParams};
use smash::kernels::run_smash;

fn report(label: &str, kcfg: &KernelConfig, a: &smash::formats::Csr, b: &smash::formats::Csr) {
    let scfg = SimConfig::piuma_block();
    let r = run_smash(a, b, kcfg, &scfg).report;
    println!(
        "{:<34} {:>10.2} sim-ms  IPC {:>4.2}  DRAM {:>5.1}%  util {:>5.1}%  probes {:>5.2}  windows {}",
        label,
        r.ms,
        r.ipc,
        r.dram_util * 100.0,
        r.avg_utilization * 100.0,
        r.table.mean_probes(),
        r.windows
    );
}

fn main() {
    println!("# Ablations (R-MAT 2^11, ~34K nnz per input)\n");
    let a = rmat(&RmatParams::new(11, 34_000, 0xA));
    let b = rmat(&RmatParams::new(11, 34_000, 0xB));

    println!("## Hash bits (scheduling fixed at tokenized, SPAD table)");
    let mut k = KernelConfig::v2();
    k.hash_bits = HashBits::High;
    report("high-order bits (V1 hashing)", &k, &a, &b);
    k.hash_bits = HashBits::Low;
    report("low-order/scrambled (V2 hashing)", &k, &a, &b);

    println!("\n## Scheduling (hashing fixed at V2's)");
    let mut k = KernelConfig::v2();
    k.scheduling = Scheduling::StaticRoundRobin;
    report("static round-robin (V1 sched)", &k, &a, &b);
    k.scheduling = Scheduling::Tokenized;
    report("tokenized producer-consumer", &k, &a, &b);

    println!("\n## Table placement (V2 base)");
    let mut k = KernelConfig::v2();
    k.placement = TablePlacement::Spad;
    report("SPAD tag-data table", &k, &a, &b);
    let k = KernelConfig::v3();
    report("DRAM tag-offset + DMA (V3)", &k, &a, &b);

    println!("\n## Table load factor (V2)");
    for load in [0.25, 0.5, 0.75, 0.9] {
        let mut k = KernelConfig::v2();
        k.table_load_factor = load;
        report(&format!("load factor {load}"), &k, &a, &b);
    }

    println!("\n## Tokens per row (V2)");
    for t in [1usize, 2, 4] {
        let mut k = KernelConfig::v2();
        k.tokens_per_row = t;
        report(&format!("{t} token(s) per row"), &k, &a, &b);
    }

    println!("\n## Dense-row threshold (V2)");
    for thr in [256usize, 1024, 4096, usize::MAX] {
        let mut k = KernelConfig::v2();
        k.dense_row_threshold = thr;
        let label = if thr == usize::MAX {
            "disabled".to_string()
        } else {
            format!("threshold {thr}")
        };
        report(&label, &k, &a, &b);
    }

    println!("\n## Remote vs local hashtable (V2; §4.1.2.2 remote atomics)");
    // Windowed SMASH keeps every upsert SPAD-local; a distributed global
    // table would push (blocks-1)/blocks of upserts over the fabric.
    for blocks in [0usize, 2, 4, 8] {
        let mut k = KernelConfig::v2();
        k.remote_table_blocks = blocks;
        let label = if blocks == 0 {
            "all-local (windowed design)".to_string()
        } else {
            format!("distributed over {blocks} blocks")
        };
        report(&label, &k, &a, &b);
    }

    println!("\n## Die scale-out (V3, LPT window scheduling, small SPAD)");
    // small SPAD -> many windows so blocks have work to distribute
    let scfg = SimConfig::test_tiny();
    let mut base = None;
    for blocks in [1usize, 2, 4, 8] {
        let (_, rep) = smash::coordinator::run_die(
            &a,
            &b,
            &KernelConfig::v3(),
            &scfg,
            blocks,
            smash::coordinator::SchedPolicy::Lpt,
        );
        let b0 = *base.get_or_insert(rep.ms);
        println!(
            "{:<34} {:>10.2} sim-ms  speedup {:>4.2}x  imbalance {:.3}",
            format!("{blocks} block(s)"),
            rep.ms,
            b0 / rep.ms.max(1e-12),
            rep.imbalance
        );
    }
}
