//! Regenerates Figures 6.1–6.4: per-thread utilization timelines for the
//! unbalanced (V1) and balanced (V2) workloads, average utilization bars,
//! and the utilization histograms, plus the §6.5 single-window time claim
//! (paper: 14.15 ms -> 4.09 ms).

use smash::bench;
use smash::config::{KernelConfig, SimConfig};
use smash::kernels::run_smash;

fn main() {
    let scale = match std::env::var("SMASH_BENCH_SCALE").as_deref() {
        Ok("full") => bench::Scale::Full,
        _ => bench::Scale::Small,
    };
    println!("# Figures 6.1-6.4 (scale {scale:?})\n");
    let (a, b) = bench::paper_inputs(scale);
    let scfg = SimConfig::piuma_block();

    let (chart1, r1) = bench::fig_6_1_6_2(&a, &b, false, &scfg);
    println!("{chart1}");
    let (chart2, r2) = bench::fig_6_1_6_2(&a, &b, true, &scfg);
    println!("{chart2}");
    println!(
        "§6.5 single-window hashing time: V1 {:.2} ms -> V2 {:.2} ms ({:.1}x; paper: 14.15 -> 4.09 ms, 3.5x)\n",
        r1.first_window_ms,
        r2.first_window_ms,
        r1.first_window_ms / r2.first_window_ms.max(1e-12),
    );

    let r3 = run_smash(&a, &b, &KernelConfig::v3(), &scfg).report;
    let reports = vec![r1.clone(), r2.clone(), r3];
    println!("{}", bench::fig_6_3(&reports));
    println!("{}", bench::fig_6_4(&r1, &r2));
}
