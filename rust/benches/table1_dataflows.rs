//! Regenerates Table 1.1 (sparse graph datasets) and Table 1.2 (dataflow
//! comparison: input/output reuse, intermediate size) from measured
//! traffic counters, plus wall-clock timings of the four reference
//! dataflows (the CPU-baseline comparison of §3.1).

use smash::bench::{self, Bench};
use smash::gen::{rmat, RmatParams};
use smash::spgemm::{AccumSpec, Dataflow, SemiringKind};

fn main() {
    println!("# Table 1.1 / Table 1.2\n");
    println!("{}", bench::table_1_1(7).render());

    let a = rmat(&RmatParams::new(11, 34_000, 0xA));
    let b = rmat(&RmatParams::new(11, 34_000, 0xB));
    println!("{}", bench::table_1_2(&a, &b).render());

    println!("## Wall-clock of the reference dataflows (same inputs)\n");
    let mut bench_h = Bench::new();
    for df in Dataflow::ALL {
        bench_h.run(df.name(), || df.multiply(&a, &b));
    }
    // the multicore serving backend against the serial baselines
    for threads in [2, 4, 8] {
        let df = Dataflow::ParGustavson {
            threads,
            accum: AccumSpec::default(),
            semiring: SemiringKind::Arithmetic,
        };
        bench_h.run(&format!("{} (t={threads})", df.name()), || {
            df.multiply(&a, &b)
        });
    }
}
