//! Microbenchmarks of the hot paths that dominate the end-to-end harness
//! (the §Perf working set): R-MAT generation, CSR construction, the
//! Gustavson oracle, the SMASH hashtable, and one simulated kernel run.
//! Before/after numbers for the optimization log live in EXPERIMENTS.md.

use smash::bench::Bench;
use smash::config::{HashBits, KernelConfig, SimConfig};
use smash::formats::Csr;
use smash::gen::{rmat, RmatParams};
use smash::kernels::{run_smash, TagTable};
use smash::spgemm::{gustavson, rowwise_hash};
use smash::util::prng::Xoshiro256;

fn main() {
    let mut h = Bench::new();

    h.run("rmat_gen_2^12_100k_edges", || {
        rmat(&RmatParams::new(12, 100_000, 7))
    });

    let a = rmat(&RmatParams::new(11, 34_000, 0xA));
    let b = rmat(&RmatParams::new(11, 34_000, 0xB));

    h.run("csr_from_triplets_34k", || {
        let triplets: Vec<(usize, usize, f64)> = (0..a.rows)
            .flat_map(|r| {
                let (c, v) = a.row(r);
                c.iter().zip(v).map(move |(c, v)| (r, *c as usize, *v))
            })
            .collect();
        Csr::from_triplets(a.rows, a.cols, triplets)
    });

    h.run("csr_transpose_34k", || a.transpose());

    h.run("gustavson_oracle_2^11", || gustavson(&a, &b));

    h.run("rowwise_hash_native_2^11", || rowwise_hash(&a, &b));

    h.run("tagtable_1M_upserts", || {
        let mut t = TagTable::new(1 << 21, 22, HashBits::Low);
        let mut rng = Xoshiro256::seed_from_u64(3);
        for _ in 0..1_000_000 {
            t.upsert(rng.next_below(1 << 22), 1.0);
        }
        t.stats.upserts
    });

    h.run("smash_v3_sim_2^9", || {
        let a = rmat(&RmatParams::new(9, 6_000, 1));
        let b = rmat(&RmatParams::new(9, 6_000, 2));
        run_smash(&a, &b, &KernelConfig::v3(), &SimConfig::piuma_block())
            .report
            .cycles
    });

    h.run("smash_v2_sim_2^9", || {
        let a = rmat(&RmatParams::new(9, 6_000, 1));
        let b = rmat(&RmatParams::new(9, 6_000, 2));
        run_smash(&a, &b, &KernelConfig::v2(), &SimConfig::piuma_block())
            .report
            .cycles
    });
}
