//! Microbenchmarks of the hot paths that dominate the end-to-end harness
//! (the §Perf working set): R-MAT generation, CSR construction, the
//! Gustavson oracle, the SMASH hashtable, and one simulated kernel run.
//! Before/after numbers for the optimization log live in EXPERIMENTS.md.

use smash::bench::Bench;
use smash::config::{HashBits, KernelConfig, SimConfig};
use smash::formats::Csr;
use smash::gen::{rmat, RmatParams};
use smash::kernels::{
    insertion_sort_cost, insertion_sort_cost_quadratic, run_smash, TagTable,
};
use smash::spgemm::{gustavson, par_gustavson, rowwise_hash};
use smash::util::prng::Xoshiro256;

fn main() {
    let mut h = Bench::new();

    h.run("rmat_gen_2^12_100k_edges", || {
        rmat(&RmatParams::new(12, 100_000, 7))
    });

    let a = rmat(&RmatParams::new(11, 34_000, 0xA));
    let b = rmat(&RmatParams::new(11, 34_000, 0xB));

    h.run("csr_from_triplets_34k", || {
        let triplets: Vec<(usize, usize, f64)> = (0..a.rows)
            .flat_map(|r| {
                let (c, v) = a.row(r);
                c.iter().zip(v).map(move |(c, v)| (r, *c as usize, *v))
            })
            .collect();
        Csr::from_triplets(a.rows, a.cols, triplets)
    });

    h.run("csr_transpose_34k", || a.transpose());

    h.run("gustavson_oracle_2^11", || gustavson(&a, &b));

    h.run("par_gustavson_t4_2^11", || par_gustavson(&a, &b, 4));

    h.run("par_gustavson_t8_2^11", || par_gustavson(&a, &b, 8));

    h.run("rowwise_hash_native_2^11", || rowwise_hash(&a, &b));

    // V1 write-back sort cost: the semi-sorted drain of a high-bit table,
    // old quadratic shift counter vs. the merge-sort inversion counter
    // (identical shift totals, very different wall-clock).
    let drained = {
        let mut t = TagTable::new(1 << 16, 20, HashBits::High);
        let mut rng = Xoshiro256::seed_from_u64(9);
        for _ in 0..40_000 {
            t.upsert(rng.next_below(1 << 20), 1.0);
        }
        t.drain()
    };
    h.run("v1_writeback_sort_quadratic_40k", || {
        insertion_sort_cost_quadratic(drained.clone()).1
    });
    h.run("v1_writeback_sort_merge_40k", || {
        insertion_sort_cost(drained.clone()).1
    });

    // Worst case (the §7.2 pathology: collision walks scramble the drain
    // order): inversions ~ n²/4, where the quadratic counter's wall-clock
    // tracks the shift count and the merge counter stays n log n.
    let scrambled: Vec<(u64, f64)> = {
        let mut rng = Xoshiro256::seed_from_u64(10);
        (0..20_000).map(|_| (rng.next_below(1 << 20), 1.0)).collect()
    };
    h.run("writeback_sort_quadratic_scrambled_20k", || {
        insertion_sort_cost_quadratic(scrambled.clone()).1
    });
    h.run("writeback_sort_merge_scrambled_20k", || {
        insertion_sort_cost(scrambled.clone()).1
    });

    h.run("tagtable_1M_upserts", || {
        let mut t = TagTable::new(1 << 21, 22, HashBits::Low);
        let mut rng = Xoshiro256::seed_from_u64(3);
        for _ in 0..1_000_000 {
            t.upsert(rng.next_below(1 << 22), 1.0);
        }
        t.stats.upserts
    });

    h.run("smash_v3_sim_2^9", || {
        let a = rmat(&RmatParams::new(9, 6_000, 1));
        let b = rmat(&RmatParams::new(9, 6_000, 2));
        run_smash(&a, &b, &KernelConfig::v3(), &SimConfig::piuma_block())
            .report
            .cycles
    });

    h.run("smash_v2_sim_2^9", || {
        let a = rmat(&RmatParams::new(9, 6_000, 1));
        let b = rmat(&RmatParams::new(9, 6_000, 2));
        run_smash(&a, &b, &KernelConfig::v2(), &SimConfig::piuma_block())
            .report
            .cycles
    });
}
