//! Microbenchmarks of the hot paths that dominate the end-to-end harness
//! (the §Perf working set): R-MAT generation, CSR construction, the
//! Gustavson oracle (serial, pooled-parallel, spawn-parallel, and
//! plan-reusing), the serving coordinator's batched-vs-independent burst,
//! the SMASH hashtable, and one simulated kernel run. Before/after
//! numbers for the optimization log live in EXPERIMENTS.md.

use smash::bench::Bench;
use smash::config::{HashBits, KernelConfig, SimConfig};
use smash::coordinator::{Coordinator, Job, ServerConfig};
use smash::formats::Csr;
use smash::gen::{banded, erdos_renyi, rmat, RmatParams};
use smash::kernels::{
    insertion_sort_cost, insertion_sort_cost_quadratic, run_smash, TagTable,
};
use smash::spgemm::{
    gustavson, par_gustavson, par_gustavson_accum, par_gustavson_blocked_with_plan_policy,
    par_gustavson_kind, par_gustavson_spawning, par_gustavson_spec, par_gustavson_with_plan,
    par_gustavson_with_plan_policy, rowwise_hash, spgemm_semiring, symbolic_plan, AccumMode,
    AccumSpec, BandSpec, SemiringKind,
};
use smash::util::prng::Xoshiro256;
use std::sync::Arc;

fn main() {
    let mut h = Bench::new();

    h.run("rmat_gen_2^12_100k_edges", || {
        rmat(&RmatParams::new(12, 100_000, 7))
    });

    let a = rmat(&RmatParams::new(11, 34_000, 0xA));
    let b = rmat(&RmatParams::new(11, 34_000, 0xB));

    h.run("csr_from_triplets_34k", || {
        let triplets: Vec<(usize, usize, f64)> = (0..a.rows)
            .flat_map(|r| {
                let (c, v) = a.row(r);
                c.iter().zip(v).map(move |(c, v)| (r, *c as usize, *v))
            })
            .collect();
        Csr::from_triplets(a.rows, a.cols, triplets)
    });

    h.run("csr_transpose_34k", || a.transpose());

    h.run("gustavson_oracle_2^11", || gustavson(&a, &b));

    // Pooled vs spawn-per-call: the persistent WorkerPool must serve the
    // same product at least as fast as PR 1's thread::scope spawning —
    // and bitwise identical to the serial oracle either way.
    let (oracle, _) = gustavson(&a, &b);
    {
        let (cp, _) = par_gustavson(&a, &b, 4);
        let (cs, _) = par_gustavson_spawning(&a, &b, 4);
        assert_eq!(oracle.row_ptr, cp.row_ptr);
        assert_eq!(oracle.col_idx, cp.col_idx);
        assert_eq!(oracle.data, cp.data, "pooled backend must match the oracle bitwise");
        assert_eq!(oracle.data, cs.data, "spawn backend must match the oracle bitwise");
    }
    h.run("par_gustavson_t4_pooled_2^11", || par_gustavson(&a, &b, 4));

    h.run("par_gustavson_t4_spawn_2^11", || {
        par_gustavson_spawning(&a, &b, 4)
    });

    h.run("par_gustavson_t8_pooled_2^11", || par_gustavson(&a, &b, 8));

    // Symbolic amortization: the plan alone, then numeric-only execution
    // against a cached plan (what every post-first job in a batched
    // serving burst pays).
    h.run("symbolic_plan_t4_2^11", || symbolic_plan(&a, &b, 4));

    let shared_plan = symbolic_plan(&a, &b, 4);
    {
        let (cw, _) = par_gustavson_with_plan(&a, &b, 4, &shared_plan);
        assert_eq!(oracle.data, cw.data, "plan-reusing backend must match the oracle bitwise");
    }
    h.run("par_gustavson_t4_cached_plan_2^11", || {
        par_gustavson_with_plan(&a, &b, 4, &shared_plan)
    });

    h.run("rowwise_hash_native_2^11", || rowwise_hash(&a, &b));

    // ---- Adaptive hybrid accumulator sweeps (the tentpole): adaptive vs
    // forced-dense vs forced-hash on four input shapes, every variant
    // asserted bitwise against the serial oracle before timing.
    let accum_inputs: Vec<(&str, Csr, Csr)> = vec![
        ("rmat_2^11", a.clone(), b.clone()),
        (
            "erdos_2^11",
            erdos_renyi(1 << 11, 34_000, 0xC),
            erdos_renyi(1 << 11, 34_000, 0xD),
        ),
        ("banded_2^11", banded(1 << 11, 8, 0xE), banded(1 << 11, 8, 0xF)),
        (
            // Hypersparse wide: 2^18 columns, ~0.15 nnz/row, no hub rows
            // — the shape that makes O(b.cols)-per-worker dense scratch
            // unservable, and where every row's FLOPs bound sits far
            // under the cols/16 threshold.
            "hypersparse_2^18",
            erdos_renyi(1 << 18, 40_000, 0x10),
            erdos_renyi(1 << 18, 40_000, 0x11),
        ),
    ];
    for (name, ai, bi) in &accum_inputs {
        let (oracle, _) = gustavson(ai, bi);
        for mode in [
            AccumMode::Adaptive,
            AccumMode::Dense,
            AccumMode::Hash,
            AccumMode::Merge,
        ] {
            let (c, t) = par_gustavson_accum(ai, bi, 4, mode);
            assert_eq!(oracle.row_ptr, c.row_ptr, "{name}/{}", mode.name());
            assert_eq!(oracle.col_idx, c.col_idx, "{name}/{}", mode.name());
            assert_eq!(
                oracle.data,
                c.data,
                "{name}/{}: accumulator must match the oracle bitwise",
                mode.name()
            );
            if *name == "hypersparse_2^18" {
                println!(
                    "  [{name}/{}] peak worker accumulator bytes: {} (dense lane floor: {})",
                    mode.name(),
                    t.accum.peak_bytes,
                    bi.cols * 9,
                );
                if mode == AccumMode::Adaptive {
                    // The acceptance bar: per-worker accumulator memory is
                    // O(live row nnz), not O(b.cols).
                    assert!(
                        t.accum.peak_bytes * 2 < (bi.cols * 9) as u64,
                        "adaptive accumulator must stay far under the dense floor: \
                         {} vs {}",
                        t.accum.peak_bytes,
                        bi.cols * 9
                    );
                }
            }
            h.run(&format!("par_gustavson_t4_{}_{name}", mode.name()), || {
                par_gustavson_accum(ai, bi, 4, mode)
            });
        }
        // The per-matrix heuristic threshold (`--accum auto`, the tune
        // subsystem's pick) — bitwise-checked like the fixed modes.
        let (c_auto, _, policy) = par_gustavson_spec(ai, bi, 4, AccumSpec::Auto);
        assert_eq!(
            oracle.data, c_auto.data,
            "{name}/auto ({}): must match the oracle bitwise",
            policy.describe()
        );
        h.run(&format!("par_gustavson_t4_auto_{name}"), || {
            par_gustavson_spec(ai, bi, 4, AccumSpec::Auto)
        });
    }

    // ---- Semiring sweep (the graph fast path): all four semirings
    // through the pooled parallel backend on the same 2^11 R-MAT pair,
    // each bitwise-checked against the serial semiring oracle before
    // timing. The arithmetic leg doubles as the no-regression baseline
    // for the semiring generalization (compare with
    // par_gustavson_t4_pooled_2^11 above).
    for kind in SemiringKind::ALL {
        let oracle = spgemm_semiring(&a, &b, kind);
        let (c, t, _) = par_gustavson_kind(&a, &b, 4, AccumSpec::default(), kind);
        assert_eq!(oracle.row_ptr, c.row_ptr, "{}", kind.name());
        assert_eq!(oracle.col_idx, c.col_idx, "{}", kind.name());
        assert_eq!(
            oracle.data,
            c.data,
            "{}: parallel semiring product must match the serial oracle bitwise",
            kind.name()
        );
        assert_eq!(
            t.accum.dense_rows + t.accum.hash_rows + t.accum.merge_rows,
            a.rows as u64
        );
        h.run(&format!("par_gustavson_t4_semiring_{}_2^11", kind.name()), || {
            par_gustavson_kind(&a, &b, 4, AccumSpec::default(), kind)
        });
    }

    // ---- Propagation blocking (the banded backend): blocked vs
    // unblocked on the hypersparse 2^18-column pair — the wide shape
    // banding exists for — sharing ONE symbolic plan so the diff is pure
    // numeric-pass cost. Every band width is bitwise-asserted against
    // the serial oracle before timing, and the band stats must bound the
    // dense accumulator lane by the configured band.
    {
        let (_, ai, bi) = accum_inputs
            .iter()
            .find(|(n, _, _)| *n == "hypersparse_2^18")
            .expect("hypersparse pair present");
        let (oracle, _) = gustavson(ai, bi);
        let plan = symbolic_plan(ai, bi, 4);
        for (label, spec) in [("auto", BandSpec::Auto), ("64", BandSpec::Cols(64))] {
            let band_cols = spec.resolve(bi.cols);
            let policy = AccumSpec::Auto.resolve(band_cols, &plan.row_flops);
            let (c, t) =
                par_gustavson_blocked_with_plan_policy(ai, bi, 4, &plan, policy, band_cols);
            assert_eq!(oracle.row_ptr, c.row_ptr, "blocked/{label}");
            assert_eq!(oracle.col_idx, c.col_idx, "blocked/{label}");
            assert_eq!(
                oracle.data,
                c.data,
                "blocked/{label}: banded product must match the oracle bitwise"
            );
            assert!(
                t.band.max_dense_lane_cols <= band_cols as u64,
                "blocked/{label}: dense lane ({}) must fit the band ({band_cols})",
                t.band.max_dense_lane_cols
            );
            h.run(
                &format!("par_gustavson_t4_blocked_{label}_hypersparse_2^18"),
                || par_gustavson_blocked_with_plan_policy(ai, bi, 4, &plan, policy, band_cols),
            );
        }
        let policy = AccumSpec::Auto.resolve(bi.cols, &plan.row_flops);
        let (c, _) = par_gustavson_with_plan_policy(ai, bi, 4, &plan, policy);
        assert_eq!(oracle.data, c.data, "unblocked baseline must stay bitwise-oracle");
        h.run("par_gustavson_t4_unblocked_hypersparse_2^18", || {
            par_gustavson_with_plan_policy(ai, bi, 4, &plan, policy)
        });
    }

    // Batched vs independent serving: a 16-job burst against one
    // registered operand pair, with the coordinator's symbolic cache on
    // (one symbolic pass, 15 reuses) vs off (16 independent passes).
    let a_shared = Arc::new(a.clone());
    let b_shared = Arc::new(b.clone());
    let serve_burst = |symbolic_cache: bool| {
        let mut coord = Coordinator::start(ServerConfig {
            workers: 2,
            queue_depth: 32,
            symbolic_cache,
            ..ServerConfig::default()
        });
        let id_a = coord.register_arc("A", Arc::clone(&a_shared));
        let id_b = coord.register_arc("B", Arc::clone(&b_shared));
        for _ in 0..16 {
            coord
                .try_submit(Job::pair(id_a, id_b).threads(2))
                .expect("burst admission is unbounded");
        }
        let responses = coord.collect_all();
        let nnz: usize = responses.values().map(|r| r.c.nnz()).sum();
        coord.shutdown();
        nnz
    };
    h.run("serve_burst16_batched_2^11", || serve_burst(true));
    h.run("serve_burst16_independent_2^11", || serve_burst(false));

    // V1 write-back sort cost: the semi-sorted drain of a high-bit table,
    // old quadratic shift counter vs. the merge-sort inversion counter
    // (identical shift totals, very different wall-clock).
    let drained = {
        let mut t = TagTable::new(1 << 16, 20, HashBits::High);
        let mut rng = Xoshiro256::seed_from_u64(9);
        for _ in 0..40_000 {
            t.upsert(rng.next_below(1 << 20), 1.0);
        }
        t.drain()
    };
    h.run("v1_writeback_sort_quadratic_40k", || {
        insertion_sort_cost_quadratic(drained.clone()).1
    });
    h.run("v1_writeback_sort_merge_40k", || {
        insertion_sort_cost(drained.clone()).1
    });

    // Worst case (the §7.2 pathology: collision walks scramble the drain
    // order): inversions ~ n²/4, where the quadratic counter's wall-clock
    // tracks the shift count and the merge counter stays n log n.
    let scrambled: Vec<(u64, f64)> = {
        let mut rng = Xoshiro256::seed_from_u64(10);
        (0..20_000).map(|_| (rng.next_below(1 << 20), 1.0)).collect()
    };
    h.run("writeback_sort_quadratic_scrambled_20k", || {
        insertion_sort_cost_quadratic(scrambled.clone()).1
    });
    h.run("writeback_sort_merge_scrambled_20k", || {
        insertion_sort_cost(scrambled.clone()).1
    });

    h.run("tagtable_1M_upserts", || {
        let mut t = TagTable::new(1 << 21, 22, HashBits::Low);
        let mut rng = Xoshiro256::seed_from_u64(3);
        for _ in 0..1_000_000 {
            t.upsert(rng.next_below(1 << 22), 1.0);
        }
        t.stats.upserts
    });

    h.run("smash_v3_sim_2^9", || {
        let a = rmat(&RmatParams::new(9, 6_000, 1));
        let b = rmat(&RmatParams::new(9, 6_000, 2));
        run_smash(&a, &b, &KernelConfig::v3(), &SimConfig::piuma_block())
            .report
            .cycles
    });

    h.run("smash_v2_sim_2^9", || {
        let a = rmat(&RmatParams::new(9, 6_000, 1));
        let b = rmat(&RmatParams::new(9, 6_000, 2));
        run_smash(&a, &b, &KernelConfig::v2(), &SimConfig::piuma_block())
            .report
            .cycles
    });
}
